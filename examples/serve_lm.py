"""Serve a (reduced-config) assigned architecture with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "gemma-7b", "--requests", "6"])

from repro.launch.serve import main

if __name__ == "__main__":
    main()
