"""Quickstart: the paper's model-parallel FNO in 60 lines.

Runs on CPU with 8 simulated devices: builds a small 4-D FNO, checks that
the domain-decomposed forward (paper Alg. 1/2) matches the serial oracle to
float precision, compares against the paper's pipeline-parallel baseline,
and trains a few steps with the distributed step.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FNOConfig, fno_forward, init_params, make_dist_forward,
    make_pipeline_forward, mse_loss, param_specs,
)
from repro.core.partition import make_mesh
from repro.train import AdamWConfig, adamw_update, init_opt_state

cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=8,
                in_channels=1, out_channels=1, n_blocks=4, decoder_dim=16)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 16, 16, 8, 8))
y = jnp.tanh(jnp.roll(x, 1, axis=2))  # synthetic target

# --- serial oracle vs domain decomposition (2 data x 4 model devices) ----
mesh = make_mesh((2, 4), ("data", "model"))
fwd_dd = make_dist_forward(mesh, cfg, dp_axes=("data",))
out_serial = jax.jit(lambda p, x: fno_forward(p, x, cfg))(params, x)
out_dd = jax.jit(fwd_dd)(params, x)
np.testing.assert_allclose(np.asarray(out_dd), np.asarray(out_serial), rtol=1e-4, atol=1e-5)
print(f"domain-decomposed == serial  (max diff {float(jnp.abs(out_dd - out_serial).max()):.2e})")

# --- BEYOND-PAPER: 2-D pencil decomposition (2 data x 2 mx x 2 my) --------
# Algorithm 2 shards a single spatial dim, capping model parallelism at
# nx/2mx devices. Passing a PAIR of mesh axes as model_axis shards the
# solution along BOTH x and y (two per-axis all-to-alls; spectral weights
# sharded k_y x k_z), lifting the cap to (nx/2mx)*(ny/2my).
mesh_2d = make_mesh((2, 2, 2), ("data", "mx", "my"))
fwd_2d = make_dist_forward(mesh_2d, cfg, dp_axes=("data",), model_axis=("mx", "my"))
out_2d = jax.jit(fwd_2d)(params, x)
np.testing.assert_allclose(np.asarray(out_2d), np.asarray(out_serial), rtol=1e-4, atol=1e-5)
print(f"2-D pencil-decomposed == serial (max diff {float(jnp.abs(out_2d - out_serial).max()):.2e})")

# --- BEYOND-PAPER: fused Pallas spectral path + overlapped all-to-alls ----
# use_pallas=True routes every FNO block's spectral core through one Pallas
# kernel that fuses mode truncation + the complex channel mix + zero-pad
# (one HBM pass instead of three materializations; interpret-mode on CPU,
# compiled on TPU), and comm_chunks=2 splits each pencil all-to-all into
# channel chunks so XLA's latency-hiding scheduler can fly chunk i's wires
# under chunk i+1's local FFTs. Both are bit-for-bit drop-ins: same params,
# same numerics gate as above. Serving additionally caches the weights'
# re/im planes once per checkpoint (params_with_planes) instead of
# re-splitting them every block of every rollout step. Shell:
#   python src/repro/launch/train.py --mode fno ... --use-pallas --comm-chunks 2
#   python src/repro/launch/serve_pde.py --ckpt-dir ... --use-pallas --verify
import dataclasses

fused_cfg = dataclasses.replace(cfg, use_pallas=True, comm_chunks=2)
fwd_fused = make_dist_forward(mesh, fused_cfg, dp_axes=("data",))
out_fused = jax.jit(fwd_fused)(params, x)
np.testing.assert_allclose(np.asarray(out_fused), np.asarray(out_serial), rtol=1e-4, atol=1e-5)
print(f"fused Pallas spectral path == serial (max diff "
      f"{float(jnp.abs(out_fused - out_serial).max()):.2e})")

# --- the paper's pipeline-parallel comparison baseline --------------------
mesh_pp = make_mesh((1, 4), ("data", "model"))
fwd_pp = make_pipeline_forward(mesh_pp, cfg, n_micro=2)
out_pp = jax.jit(fwd_pp)(params, x)
np.testing.assert_allclose(np.asarray(out_pp), np.asarray(out_serial), rtol=1e-4, atol=1e-5)
print("pipeline baseline matches too (but see Fig. 6: its bubble efficiency "
      "is M/(M+P-1) = 0.4 here vs ~1.0 for domain decomposition)")

# --- train a few steps with the distributed forward -----------------------
opt_cfg = AdamWConfig(lr=2e-2)
opt = init_opt_state(params)

@jax.jit
def train_step(params, opt, x, y):
    def loss_fn(p):
        return mse_loss(fwd_dd(p, x), y)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
    return params, opt, loss

losses = []
for step in range(40):
    params, opt, loss = train_step(params, opt, x, y)
    losses.append(float(loss))
    if step % 10 == 0 or step == 39:
        print(f"step {step:3d}  loss {losses[-1]:.5f}")
assert losses[-1] < losses[0], "loss should decrease"

# --- END TO END: cloud datagen -> chunked store -> sharded training -------
# The paper's full pipeline: simulate training pairs in parallel through the
# batch pool, write them spatially chunked (x * y) into the array store with
# streaming normalization stats, then train with every device reading ONLY
# the chunks under its (mx, my) pencil — assembled into globally-sharded
# batches by the ShardedDatasetLoader and consumed via shard_train_step.
# The same thing, from a shell:
#   python -m repro.launch.datagen --pde two_phase --n 8 \
#       --grid 16 8 8 --nt 4 --out /tmp/ds
#   python src/repro/launch/train.py --mode fno --x-store /tmp/ds/x \
#       --y-store /tmp/ds/y --devices 8 --model-shards 2 2
import tempfile

from jax.sharding import PartitionSpec as P
from repro.core.fno import input_spec
from repro.data import ArrayStore, ShardedDatasetLoader
from repro.launch.datagen import main as datagen
from repro.train import init_opt_state as init_opt, make_train_step
from repro.train.train_loop import shard_train_step

with tempfile.TemporaryDirectory() as tmp:
    datagen([
        "--pde", "two_phase", "--n", "8", "--grid", "16", "8", "8",
        "--nt", "4", "--out", f"{tmp}/ds", "--backend", "thread",
    ])
    xs, ys = ArrayStore.open(f"{tmp}/ds/x"), ArrayStore.open(f"{tmp}/ds/y")
    print(f"stats from meta.json: x mean {xs.meta['stats']['mean'][0]:.4f} "
          f"std {xs.meta['stats']['std'][0]:.4f}")

    e2e_cfg = FNOConfig(grid=(16, 8, 8, 4), modes=(4, 2, 2, 2), width=8,
                        n_blocks=2, decoder_dim=16)
    fwd = make_dist_forward(mesh_2d, e2e_cfg, dp_axes=("data",),
                            model_axis=("mx", "my"))
    spec = input_spec(("data",), ("mx", "my"))
    jit_step = shard_train_step(
        make_train_step(
            lambda p, b: (mse_loss(fwd(p, b["x"]), b["y"]), {}),
            AdamWConfig(lr=3e-3),
        ),
        mesh_2d,
        param_specs(mesh_2d, ("mx", "my")),
        jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), e2e_cfg)),
        {"x": spec, "y": spec},
    )
    p2 = init_params(jax.random.PRNGKey(0), e2e_cfg)
    o2 = init_opt(p2)
    with ShardedDatasetLoader(
        {"x": xs, "y": ys}, mesh_2d, 2, {"x": spec, "y": spec},
        normalize=("x",),
    ) as loader:
        e2e_losses = []
        for step in range(10):
            p2, o2, m = jit_step(p2, o2, loader.batch(step))
            e2e_losses.append(float(m["loss"]))
    print(f"end-to-end sharded training: loss {e2e_losses[0]:.3e} -> "
          f"{e2e_losses[-1]:.3e} (each device read only its pencil's chunks)")
    assert e2e_losses[-1] < e2e_losses[0]

# --- SERVE THE TRAINED SURROGATE: continuous scenario batching ------------
# The paper's payoff is inference: the surrogate replaces the numerical
# simulator for 1000s-of-scenario workloads (well placement, UQ). Serving
# goes through the SAME slot scheduler that serves LLM tokens — one batched
# model-parallel FNO application per tick, continuous admission, padded
# buckets — with the store's normalization applied on ingress and inverted
# on egress, so outputs are physical saturations.
from repro.data.loader import Normalizer
from repro.data.pde.two_phase import TwoPhaseConfig, random_well_mask
from repro.serve import FNORunner, ScenarioRequest, Scheduler

runner = FNORunner(
    e2e_cfg, p2, mesh=mesh_2d, model_axis=("mx", "my"), max_slots=4,
    x_normalizer=Normalizer.from_source(xs),
)
runner.warmup()
sim_cfg = TwoPhaseConfig(grid=e2e_cfg.grid[:3], nt_frames=e2e_cfg.grid[3])
sched = Scheduler(runner, 4)
for i in range(8):  # a small UQ ensemble of well placements
    mask = random_well_mask(sim_cfg, 2, i)
    x = np.repeat(mask[None, :, :, :, None], e2e_cfg.grid[3], -1)
    sched.submit(ScenarioRequest(rid=i, x=x.astype(np.float32), steps=2))
import time as _time

t0 = _time.perf_counter()
served = sched.run_until_done()
dt = _time.perf_counter() - t0
print(f"served {len(served)} scenarios x 2 rollout steps in {dt:.3f}s "
      f"({len(served)/dt:.1f} scen/s) over {sched.steps} engine ticks, "
      f"model-parallel on {dict(mesh_2d.shape)}")
assert all(len(r.outputs) == 2 for r in served)
# From a shell, the same thing runs off a train.py checkpoint directory
# (train.py persists fno_config.json — architecture + normalization
# snapshot — next to its checkpoints):
#   python src/repro/launch/serve_pde.py --ckpt-dir /tmp/ckpt \
#       --scenarios 64 --max-batch 8 --devices 8 --model-shards 2 2 \
#       --verify --bench-sequential --reference

# --- UQ ENSEMBLE + GEOMODEL CACHE: the KV-cache of PDE serving ------------
# Real UQ ensembles share ONE permeability geomodel across thousands of
# scenarios — only the wells move. Declaring the leading input channels
# static (n_static) makes the runner cache their normalized form and
# encoder prelift by content hash: computed once, replayed for every
# request AND rollout step (the forward lifts only the dynamic channels
# and adds the cached partial sum — bit-identical to recomputing). The
# scheduler additionally dedups byte-identical in-flight scenarios: a
# duplicate never occupies a slot, it receives the primary's outputs.
from repro.launch.datagen import geomodel_channel

uq_cfg = FNOConfig(grid=(16, 8, 8, 4), modes=(4, 2, 2, 2), width=8,
                   n_blocks=2, decoder_dim=16, in_channels=2)
uq_runner = FNORunner(
    uq_cfg, init_params(jax.random.PRNGKey(2), uq_cfg), mesh=mesh_2d,
    model_axis=("mx", "my"), max_slots=4, n_static=1,
)
uq_runner.warmup()
geo = geomodel_channel(uq_cfg.grid[:3], uq_cfg.grid[3])  # shared geomodel
sched = Scheduler(uq_runner, 4)
for i in range(8):
    mask = random_well_mask(sim_cfg, 2, 100 + i)
    well = np.repeat(mask[None, :, :, :, None], uq_cfg.grid[3], -1)
    x = np.concatenate([geo, well.astype(np.float32)], axis=0)
    sched.submit(ScenarioRequest(rid=i, x=x, steps=2))
    sched.submit(ScenarioRequest(rid=100 + i, x=x.copy(), steps=2))  # dup
served = sched.run_until_done()
cache_stats = uq_runner.cache.stats
print(f"UQ ensemble: {len(served)} scenarios served, geomodel-cache "
      f"hit-rate {cache_stats['hit_rate']:.2f} ({cache_stats['hits']} hits /"
      f" {cache_stats['misses']} misses), dedup absorbed "
      f"{sched.dedup_attached} duplicate(s)")
assert cache_stats["hit_rate"] > 0 and sched.dedup_attached == 8
# Shell version — datagen --geomodel writes the log-permeability field as
# a static input channel, so the trained checkpoint serves in ensemble
# mode (vary wells only, report hit-rate; benchmarks/run.py cache measures
# the cold-vs-warm throughput gain):
#   python -m repro.launch.datagen --pde two_phase --geomodel --n 8 \
#       --grid 16 8 8 --nt 4 --out /tmp/geo_ds
#   python src/repro/launch/train.py --mode fno --x-store /tmp/geo_ds/x \
#       --y-store /tmp/geo_ds/y --ckpt-dir /tmp/geo_ckpt
#   python src/repro/launch/serve_pde.py --ckpt-dir /tmp/geo_ckpt \
#       --ensemble --static-channels 1 --dup 2 --verify

# --- FLEET SERVING: a gateway over N replicas -----------------------------
# Production scenario traffic outgrows one scheduler: the Gateway fronts N
# independent replicas (each its own runner + scheduler — in production
# its own host / mesh slice) and ROUTES requests: "affinity" keeps every
# scenario sharing a geomodel on the replica that already cached it, so
# the fleet-wide hit-rate matches the single-process rate and duplicates
# still dedup; a replica whose runner raises is drained and its requests
# fail over to the healthy ones. With one replica the gateway is a
# pass-through — outputs stay bit-identical to the plain scheduler. Two
# geomodel realizations below -> each pins to its own replica, so every
# replica's cache serves exactly one geomodel (the fleet hit-rate match).
from repro.serve import Gateway

fleet = [uq_runner]
for _ in range(1):  # replicate the SAME checkpoint (heterogeneous is fine)
    extra = FNORunner(
        uq_cfg, init_params(jax.random.PRNGKey(2), uq_cfg), mesh=mesh_2d,
        model_axis=("mx", "my"), max_slots=4, n_static=1,
    )
    extra.warmup()
    fleet.append(extra)
gateway = Gateway(fleet, policy="affinity")
geo2 = geomodel_channel(uq_cfg.grid[:3], uq_cfg.grid[3], seed=1)
for i in range(8):
    mask = random_well_mask(sim_cfg, 2, 200 + i)
    well = np.repeat(mask[None, :, :, :, None], uq_cfg.grid[3], -1)
    x = np.concatenate([(geo, geo2)[i % 2], well.astype(np.float32)], axis=0)
    gateway.submit(ScenarioRequest(rid=200 + i, x=x, steps=2))
served = gateway.run_until_done()
stats = gateway.stats()
for rs in stats["replicas"]:
    print(f"  replica {rs['name']}: routed {rs['routed']}, served "
          f"{rs['finished']}, backlog {rs['pending']}")
print(f"fleet: {len(served)} served across {stats['fleet']['n_replicas']} "
      f"replicas, cache hit-rate {stats['fleet']['cache_hit_rate']:.2f}")
assert len(served) == 8 and not gateway.failed
# Shell version (2 replicas restored from one checkpoint; benchmarks/run.py
# gateway measures fleet scenarios/s + p95 vs single-replica under
# open-loop arrivals):
#   python src/repro/launch/serve_pde.py --ckpt-dir /tmp/geo_ckpt \
#       --replicas 2 --policy affinity --ensemble --static-channels 1 \
#       --dup 2 --verify

# --- DEEP CACHE + FLEET-SHARED STORE --------------------------------------
# The geomodel cache goes two levels past the encoder prelift: with
# cache_level="deep" (the default) the runner also caches the first
# spectral block's STATIC kept-mode spectra and weight-mixed contribution.
# FFT -> truncate -> mix is linear, so block 0 runs only on the dynamic
# remainder and the cached contribution is summed straight into its
# pre-activation (core.fno.fno_forward_deep_split) — bit-identical to
# recomputing, but the whole static spectral prefix is off the per-tick
# path. A fleet-shared CacheStore adds the disaggregated tier behind the
# per-replica LRUs: replicas consult it on local miss and publish fresh
# entries, so a geomodel warmed anywhere is warm fleet-wide — including on
# the replica that inherits an ensemble after a failover re-route.
from repro.serve import DictCacheStore

store = DictCacheStore()  # FileCacheStore(path) for cross-process fleets
deep_fleet = []
for _ in range(2):
    rep = FNORunner(
        uq_cfg, init_params(jax.random.PRNGKey(2), uq_cfg), mesh=mesh_2d,
        model_axis=("mx", "my"), max_slots=4, n_static=1,
        cache_level="deep", cache_store=store,
    )
    rep.warmup()
    deep_fleet.append(rep)
gw2 = Gateway(deep_fleet, policy="affinity")
for i in range(6):
    mask = random_well_mask(sim_cfg, 2, 300 + i)
    well = np.repeat(mask[None, :, :, :, None], uq_cfg.grid[3], -1)
    x = np.concatenate([geo, well.astype(np.float32)], axis=0)
    gw2.submit(ScenarioRequest(rid=300 + i, x=x, steps=2))
served = gw2.run_until_done()
pinned = max(gw2.replicas, key=lambda h: h.routed)  # affinity pins the geo
lv = pinned.runner.cache.stats["level_bytes"]
print(f"deep cache on {pinned.name}: level bytes "
      + ", ".join(f"{k}={v}" for k, v in lv.items())
      + f"; shared store {store.stats['puts']} put(s), "
      f"{store.stats['entries']} entr(y/ies)")
assert len(served) == 6 and not gw2.failed
assert lv["spectra"] > 0 and lv["contribution"] > 0  # the deep levels
assert store.stats["puts"] >= 1  # published for the rest of the fleet
# Shell version (per-level warm-vs-cold speedup + a simulated replica
# failover with a cross-replica store hit live in benchmarks/run.py cache;
# results persist to BENCH_cache.json):
#   python src/repro/launch/serve_pde.py --ckpt-dir /tmp/geo_ckpt \
#       --ensemble --static-channels 1 --cache-level deep \
#       --cache-store /tmp/fleet_store --replicas 2 --verify

# --- ONLINE TRAINING: train while the simulator is still writing ----------
# The paper's biggest adoption cost is that the dataset "must be simulated
# in advance". The streaming path removes it (Meyer-et-al online learning):
# ONE command spawns datagen in the background and starts stepping as soon
# as the first batch's samples are published, drawing every batch from the
# store's complete-prefix watermark. The per-step watermarks are recorded
# to <ckpt-dir>/watermarks.json, so after a crash + restore — or replayed
# against the finished store — the sample schedule is bit-identical, and
# back-pressure (with a stall counter in the final report) kicks in if
# training outpaces simulation:
#
#   python src/repro/launch/train.py --mode fno --online --out /tmp/ds \
#       --pde two_phase --n-data 16 --grid 16 8 8 4 \
#       --devices 8 --model-shards 2 2
#
# The run prints "online: first step with K/N samples complete ...
# overlap=True" — training began while simulation was in flight. Compare
# time-to-first-step against simulate-then-train with:
#   PYTHONPATH=src:. python benchmarks/run.py streaming
print("quickstart OK")
