"""End-to-end driver: simulate CO2 data -> train the FNO surrogate -> eval.

The §V-B pipeline at CPU scale: two-phase Darcy simulations (OPM stand-in)
generate training pairs through the cloud batch layer; a 4-D FNO trains on
them with checkpointing + fault injection (restart mid-run, on purpose);
held-out MSE/MAE/R2 are reported like the paper's Table I.

    PYTHONPATH=src python examples/train_fno_co2.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.cloud import BatchPool, LocalProcessBackend
from repro.core import FNOConfig, fno_forward, init_params, mse_loss
from repro.data.pde.two_phase import simulate_task
from repro.train import AdamWConfig, init_opt_state, make_train_step, warmup_cosine
from repro.train.fault import FaultInjector, run_supervised

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--n-train", type=int, default=12)
ap.add_argument("--n-test", type=int, default=4)
ap.add_argument("--grid", type=int, nargs=3, default=(16, 8, 8))
ap.add_argument("--nt", type=int, default=4)
args = ap.parse_args()

# --- 1. simulate the dataset in parallel (the "Redwood" step) -------------
n_total = args.n_train + args.n_test
with tempfile.TemporaryDirectory() as tmp:
    pool = BatchPool(LocalProcessBackend(4), store_root=f"{tmp}/blobs", vm_type="E8s_v3", n_vms=4)
    results = pool.map(
        simulate_task, [(seed, 2, tuple(args.grid), args.nt) for seed in range(n_total)]
    )
    print("datagen:", pool.cost_report())
    pool.shutdown()

masks = np.stack([m for m, _ in results])  # [n, nx, ny, nz]
sats = np.stack([s for _, s in results])   # [n, nx, ny, nz, nt]
# FNO inputs: well mask repeated along t (paper: binary map repeated in t)
x = np.repeat(masks[:, None, :, :, :, None], args.nt, axis=-1).astype(np.float32)
y = sats[:, None].astype(np.float32)
x_tr, x_te = x[: args.n_train], x[args.n_train :]
y_tr, y_te = y[: args.n_train], y[args.n_train :]

# --- 2. train with checkpoint/restart + an injected failure ---------------
grid4 = tuple(args.grid) + (args.nt,)
cfg = FNOConfig(grid=grid4, modes=(4, 2, 2, 2), width=12, n_blocks=4, decoder_dim=32)
opt_cfg = AdamWConfig(lr=warmup_cosine(2e-3, 10, args.steps))
step_fn = jax.jit(make_train_step(lambda p, b: (mse_loss(fno_forward(p, b["x"], cfg), b["y"]), {}), opt_cfg))


def init_state():
    p = init_params(jax.random.PRNGKey(0), cfg)
    return {"params": p, "opt": init_opt_state(p)}


def train_step(state, batch):
    p, o, m = step_fn(state["params"], state["opt"], batch)
    return {"params": p, "opt": o}, m


def batches(step):
    i = (2 * step) % args.n_train
    sel = [i, (i + 1) % args.n_train]
    return {"x": jnp.asarray(x_tr[sel]), "y": jnp.asarray(y_tr[sel])}


with tempfile.TemporaryDirectory() as ckpt:
    res = run_supervised(
        init_state=init_state,
        train_step=train_step,
        batch_iter=batches,
        total_steps=args.steps,
        ckpt_dir=ckpt,
        save_every=25,
        injector=FaultInjector([args.steps // 2]),  # crash mid-run, recover
    )
    state = None
    print(
        f"train: {res.final_step} steps, {res.failures} failure(s), "
        f"{res.restores} restore(s), loss "
        f"{res.metrics_log[0][1]['loss']:.5f} -> {res.metrics_log[-1][1]['loss']:.5f}"
    )
    # reload final params for eval
    from repro.train import checkpoint as ck

    abstract = jax.eval_shape(init_state)
    state, _, _ = ck.restore(ckpt, abstract)

# --- 3. held-out evaluation (Table I analog) -------------------------------
pred = jax.jit(lambda p, xx: fno_forward(p, xx, cfg))(state["params"], jnp.asarray(x_te))
err = np.asarray(pred) - y_te
mse = float(np.mean(err**2))
mae = float(np.mean(np.abs(err)))
ss_res = np.sum(err**2)
ss_tot = np.sum((y_te - y_te.mean()) ** 2)
r2 = 1.0 - ss_res / ss_tot
print(f"test: MSE {mse:.3e}  MAE {mae:.4f}  R2 {r2:.4f}  (paper Table I CO2: MSE 1.16e-4, R2 0.949)")
