"""Clusterless training-data generation (the paper's Redwood workflow).

Spins up a local "batch pool" (process workers standing in for Azure Batch
VMs), broadcasts shared config through the object store, runs Navier-Stokes
simulations in parallel, writes each training pair into the chunked array
store, and prints the cost/scaling report — the §V-A pipeline end to end.

    PYTHONPATH=src python examples/datagen_cloud.py
"""
import tempfile

import numpy as np

from repro.cloud import BatchPool, LocalProcessBackend, SimBackend, SimConfig
from repro.data.pde.navier_stokes import simulate_task
from repro.data.store import ArrayStore

N_TASKS = 8
GRID, NT = 16, 4

with tempfile.TemporaryDirectory() as tmp:
    pool = BatchPool(
        LocalProcessBackend(max_workers=4),
        store_root=f"{tmp}/blobs",
        vm_type="E4s_v3",
        n_vms=4,
    )
    # sphere centers vary per task (the paper varies sphere location)
    rng = np.random.default_rng(0)
    centers = [tuple(rng.uniform(0.25, 0.75, size=3)) for _ in range(N_TASKS)]

    print(f"submitting {N_TASKS} Navier-Stokes simulations to the pool...")
    results = pool.map(
        simulate_task, [(c, GRID, NT) for c in centers], speculative=True
    )

    xs = ArrayStore.create(f"{tmp}/x", (N_TASKS, GRID, GRID, GRID), "f4", (1, GRID, GRID, GRID))
    ys = ArrayStore.create(f"{tmp}/y", (N_TASKS, GRID, GRID, GRID, NT), "f4", (1, GRID, GRID, GRID, NT))
    for i, (chi, vort) in enumerate(results):
        xs.write_chunk((i, 0, 0, 0), chi[None])
        ys.write_chunk((i, 0, 0, 0, 0), vort[None])
    print(f"stored {xs.n_complete()} input chunks, {ys.n_complete()} output chunks")

    report = pool.cost_report()
    print(
        f"cost report: {report['tasks']} tasks, mean {report['mean_task_s']:.2f}s/task, "
        f"${report['usd']:.4f} on {report['vm_type']} "
        f"(speculative re-executions: {report['speculated']})"
    )
    pool.shutdown()

# --- paper-scale projection with the simulated Azure Batch backend --------
sim = SimBackend(SimConfig())
rep = sim.run_job(n_tasks=3200, n_vms=1000, task_runtime_s=15 * 60)
print(
    f"\npaper-scale projection (3200 NS tasks, 1000 VMs, 15 min/task):\n"
    f"  submission {rep.submit_time_s:.1f}s, makespan {rep.makespan_s/3600:.2f}h\n"
    f"  weak-scaling efficiency {rep.weak_scaling_efficiency(15*60)*100:.1f}% "
    f"(paper Fig. 4b metric: submission-only serial term; paper reports >99%)\n"
    f"  end-to-end efficiency {rep.end_to_end_efficiency(15*60)*100:.1f}% "
    f"(also counts VM startup + last-round quantization)"
)
