"""Blob object store: the substrate of Redwood's broadcast/fetch.

Redwood serializes ASTs/arguments to Azure Blob storage and passes
references; workers deserialize on their side. Here: pickled blobs (zstd)
on a shared filesystem root, addressed by content-hash keys — broadcast is
"put once, pass the BlobRef to every task"."""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
from typing import Any

try:
    import zstandard as zstd

    _C = zstd.ZstdCompressor(level=3)
    _D = zstd.ZstdDecompressor()
except ImportError:  # pragma: no cover
    _C = _D = None


@dataclasses.dataclass(frozen=True)
class BlobRef:
    root: str
    key: str
    nbytes: int

    def fetch(self) -> Any:
        return ObjectStore(self.root).get(self)


class ObjectStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def put(self, obj: Any) -> BlobRef:
        raw = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if _C is not None:
            raw = _C.compress(raw)
        key = hashlib.sha1(raw).hexdigest()[:24]
        path = os.path.join(self.root, key)
        if not os.path.exists(path):  # content-addressed: dedup free
            tmp = path + f".tmp{os.getpid()}"
            with open(tmp, "wb") as f:
                f.write(raw)
            os.rename(tmp, path)
        return BlobRef(self.root, key, len(raw))

    def get(self, ref: BlobRef) -> Any:
        with open(os.path.join(self.root, ref.key), "rb") as f:
            raw = f.read()
        if _D is not None:
            raw = _D.decompress(raw)
        return pickle.loads(raw)
