"""Execution backends for the clusterless batch API.

``LocalProcessBackend``  — real parallel execution in worker processes
  (the stand-in for Azure Batch VMs in this container); tasks are
  (fn-ref, BlobRef-args) payloads resolved through the object store, like
  Redwood's runtime deserializing uploaded ASTs.
``ThreadBackend``        — in-process, for tests.
``SimBackend``           — timing/cost SIMULATION of an Azure Batch pool
  (VM startup distribution, per-task submission latency, spot preemptions)
  used by the Fig. 4/8 benchmarks; executes nothing.
"""
from __future__ import annotations

import dataclasses
import math
import os
import pickle
import random
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Any, Callable, List, Optional, Sequence

from repro.cloud.objectstore import BlobRef, ObjectStore


# -- payload resolution (the "Redwood runtime" on each worker) ---------------

def mark_task_started(store_root: str, task_id: int, t0: float) -> None:
    """Publish the task's actual start time as a tiny marker object.

    Backends queue tasks behind a finite worker pool, so submission time is
    NOT start time; the pool's straggler speculation reads these markers to
    avoid backup-submitting tasks that are merely queued (atomic rename, so
    a half-written marker is never observed)."""
    d = os.path.join(store_root, "starts")
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"task_{task_id}")
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(repr(t0))
    os.rename(tmp, path)


def read_task_started(store_root: str, task_id: int) -> Optional[float]:
    """Actual start time of a task, or None while it is still queued."""
    try:
        with open(os.path.join(store_root, "starts", f"task_{task_id}")) as f:
            return float(f.read())
    except (FileNotFoundError, ValueError):
        return None


def run_task(store_root: str, fn_ref: bytes, arg_refs: Sequence, task_id: int):
    """Worker-side entry: deserialize fn + args (BlobRefs fetched), run,
    store the result as a blob (Redwood replaces `return` with a blob
    upload), return the result's BlobRef."""
    store = ObjectStore(store_root)
    fn: Callable = pickle.loads(fn_ref)
    args = [a.fetch() if isinstance(a, BlobRef) else a for a in arg_refs]
    t0 = time.time()
    mark_task_started(store_root, task_id, t0)
    result = fn(*args)
    runtime = time.time() - t0
    ref = store.put(result)
    return {
        "task_id": task_id,
        "result_ref": ref,
        "runtime_s": runtime,
        "started_at": t0,
        "pid": os.getpid(),
    }


class LocalProcessBackend:
    """Parallel worker processes over the shared-filesystem object store."""

    def __init__(self, max_workers: int = 4):
        self.max_workers = max_workers
        self._pool = ProcessPoolExecutor(max_workers=max_workers)

    def submit(self, store_root: str, fn: Callable, arg_refs: Sequence, task_id: int):
        fn_ref = pickle.dumps(fn)
        return self._pool.submit(run_task, store_root, fn_ref, arg_refs, task_id)

    def shutdown(self):
        self._pool.shutdown(wait=True)


class ThreadBackend:
    def __init__(self, max_workers: int = 4):
        self.max_workers = max_workers
        self._pool = ThreadPoolExecutor(max_workers=max_workers)

    def submit(self, store_root: str, fn: Callable, arg_refs: Sequence, task_id: int):
        fn_ref = pickle.dumps(fn)
        return self._pool.submit(run_task, store_root, fn_ref, arg_refs, task_id)

    def shutdown(self):
        self._pool.shutdown(wait=True)


# -- simulated Azure Batch (for scaling/cost benchmarks) ---------------------

@dataclasses.dataclass
class SimConfig:
    """Calibrated to the paper's measurements:
    Fig. 4a — job submission ~16 s at 1 024 tasks (per-task upload bound);
    Fig. 8a — ~50% of 1 000 VMs up at 3.5 min, most by 6 min."""
    submit_base_s: float = 1.0          # one-time codegen + AST upload
    submit_per_task_s: float = 0.015    # per-task argument upload
    vm_startup_median_s: float = 210.0
    vm_startup_sigma: float = 0.35      # lognormal spread
    spot: bool = False
    spot_preempt_per_hour: float = 0.05
    seed: int = 0


@dataclasses.dataclass
class SimReport:
    n_tasks: int
    n_vms: int
    submit_time_s: float
    makespan_s: float
    total_core_seconds: float
    preemptions: int
    vm_ready_times: List[float]
    task_end_times: List[float]

    def weak_scaling_efficiency(self, task_runtime_s: float) -> float:
        """Paper Fig. 4b metric: the only serial component is submission,
        so eff = T_parallel_ideal / (T_parallel_ideal + T_submit)."""
        ideal = self.n_tasks * task_runtime_s / self.n_vms
        return ideal / (ideal + self.submit_time_s)

    def end_to_end_efficiency(self, task_runtime_s: float) -> float:
        """Stricter than the paper: counts VM startup + round quantization
        (useful work / pool-seconds over the real makespan)."""
        useful = self.n_tasks * task_runtime_s
        return useful / (self.n_vms * self.makespan_s)


class SimBackend:
    """Discrete-event model of a batch pool: submission, VM startup,
    greedy task placement, optional spot preemption + retry."""

    def __init__(self, cfg: SimConfig = SimConfig()):
        self.cfg = cfg

    def run_job(
        self, n_tasks: int, n_vms: int, task_runtime_s: float | Callable[[int], float]
    ) -> SimReport:
        rng = random.Random(self.cfg.seed)
        runtime = (
            task_runtime_s if callable(task_runtime_s) else (lambda i: task_runtime_s)
        )
        submit = self.cfg.submit_base_s + self.cfg.submit_per_task_s * n_tasks
        ready = sorted(
            rng.lognormvariate(math.log(self.cfg.vm_startup_median_s), self.cfg.vm_startup_sigma)
            for _ in range(n_vms)
        )
        # greedy earliest-available placement; tasks re-queued on preemption.
        # Azure Batch starts scheduling as soon as tasks arrive (paper §V-A),
        # so task i becomes available at its own submission time, overlapping
        # submission with execution.
        import heapq

        avail = [
            self.cfg.submit_base_s + self.cfg.submit_per_task_s * (i + 1)
            for i in range(n_tasks)
        ]
        vm_free = [(ready[i], i) for i in range(n_vms)]
        heapq.heapify(vm_free)
        queue = list(range(n_tasks))
        end_times = [0.0] * n_tasks
        core_seconds = 0.0
        preemptions = 0
        while queue:
            t = queue.pop(0)
            free_at, vm = heapq.heappop(vm_free)
            free_at = max(free_at, avail[t])
            dur = runtime(t)
            if self.cfg.spot:
                p = 1.0 - math.exp(-self.cfg.spot_preempt_per_hour * dur / 3600.0)
                if rng.random() < p:
                    # preempted partway: wasted work, task retried
                    frac = rng.random()
                    core_seconds += dur * frac
                    preemptions += 1
                    heapq.heappush(vm_free, (free_at + dur * frac, vm))
                    queue.append(t)
                    continue
            end = free_at + dur
            core_seconds += dur
            end_times[t] = end
            heapq.heappush(vm_free, (end, vm))
        return SimReport(
            n_tasks=n_tasks,
            n_vms=n_vms,
            submit_time_s=submit,
            makespan_s=max(end_times),
            total_core_seconds=core_seconds,
            preemptions=preemptions,
            vm_ready_times=ready,
            task_end_times=sorted(end_times),
        )
