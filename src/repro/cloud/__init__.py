"""Clusterless cloud batch layer (the paper's Redwood.jl, in Python)."""
from repro.cloud.api import BatchPool, remote, VM_PRICES, SPOT_DISCOUNT  # noqa: F401
from repro.cloud.backend import (  # noqa: F401
    LocalProcessBackend,
    SimBackend,
    SimConfig,
    ThreadBackend,
)
from repro.cloud.objectstore import BlobRef, ObjectStore  # noqa: F401
