"""Clusterless batch computing API — the Redwood.jl analog in Python.

Redwood (paper §IV-A) exposes @batchexec / @bcast / fetch over Azure Batch.
The Python equivalents here:

    pool = BatchPool(LocalProcessBackend(8), store_root="/tmp/blobs",
                     vm_type="E4s_v3", n_vms=8)
    big = pool.broadcast(velocity_model)      # upload ONCE -> BlobRef
    futs = pool.map(simulate, [(i, big) for i in range(3200)])
    results = [f.result() for f in futs]      # == fetch
    pool.cost_report()

Semantics carried over from the paper:
  * functions are executed remotely against blob-store references — the
    task payload is (pickled fn, arg refs), mirroring serialized ASTs;
  * broadcast uploads once and fans out a reference (paper Fig. 4a: the
    argument upload, not the broadcast, dominates submission);
  * tasks are independent/idempotent; results are blobs (fetch copies back);
  * straggler mitigation (beyond-paper, motivated by Fig. 8b's runtime
    tail): optional speculative re-execution of tasks slower than k x the
    median of completed ones, first finisher wins.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, List, Optional, Sequence

from repro.cloud.backend import LocalProcessBackend, read_task_started
from repro.cloud.objectstore import BlobRef, ObjectStore

# On-demand $/hr (paper's price table [53], rounded); spot ~ 0.4x.
VM_PRICES = {
    "E4s_v3": 0.25,
    "E8s_v3": 0.50,
    "HBv3": 3.60,
    "ND96amsr": 32.77,
}
SPOT_DISCOUNT = 0.4


def remote(fn: Callable) -> Callable:
    """Tag a module-level function for remote execution (@everywhere).
    Plain pickle serializes functions by reference, so remote workers must
    be able to import the module — same constraint as Redwood's @everywhere
    tagging, enforced here at submission time."""
    fn.__redwood_remote__ = True
    return fn


@dataclasses.dataclass
class TaskRecord:
    task_id: int
    submitted_at: float
    started: Optional[float] = None
    runtime_s: Optional[float] = None
    speculated: bool = False
    # BlobRefs of the first submission's uploaded args, reused verbatim by
    # speculative resubmission (paper Fig. 4a: the argument upload dominates
    # submission cost, so backup tasks must not pay it twice).
    arg_refs: Optional[List[BlobRef]] = None


class BatchFuture:
    def __init__(self, pool: "BatchPool", task_id: int, inner):
        self._pool = pool
        self.task_id = task_id
        self._inners = [inner]
        self._lock = threading.Lock()

    def add_speculative(self, inner):
        with self._lock:
            self._inners.append(inner)

    def done(self) -> bool:
        return any(i.done() for i in self._inners)

    def result(self, timeout: Optional[float] = None) -> Any:
        """Fetch: first completed attempt wins."""
        deadline = None if timeout is None else time.time() + timeout
        while True:
            for inner in list(self._inners):
                if inner.done():
                    payload = inner.result()
                    self._pool._record_finish(self.task_id, payload)
                    return payload["result_ref"].fetch()
            if deadline is not None and time.time() > deadline:
                raise TimeoutError(f"task {self.task_id}")
            time.sleep(0.005)


class BatchPool:
    def __init__(
        self,
        backend=None,
        *,
        store_root: str,
        vm_type: str = "E4s_v3",
        n_vms: int = 4,
        spot: bool = False,
    ):
        self.backend = backend or LocalProcessBackend(n_vms)
        self.store = ObjectStore(store_root)
        self.store_root = store_root
        self.vm_type = vm_type
        self.n_vms = n_vms
        self.spot = spot
        self.records: dict = {}
        self._next_id = 0
        self.submit_times: List[float] = []

    # -- primitives ---------------------------------------------------------
    def broadcast(self, obj: Any) -> BlobRef:
        return self.store.put(obj)

    def submit(self, fn: Callable, args: Sequence[Any]) -> BatchFuture:
        t0 = time.time()
        arg_refs = [a if isinstance(a, BlobRef) else self.store.put(a) for a in args]
        task_id = self._next_id
        self._next_id += 1
        inner = self.backend.submit(self.store_root, fn, arg_refs, task_id)
        self.records[task_id] = TaskRecord(
            task_id, submitted_at=time.time(), arg_refs=arg_refs
        )
        self.submit_times.append(time.time() - t0)
        return BatchFuture(self, task_id, inner)

    def map(
        self,
        fn: Callable,
        args_list: Sequence[Sequence[Any]],
        *,
        speculative: bool = False,
        straggler_factor: float = 2.0,
    ) -> List[Any]:
        futures = [self.submit(fn, args) for args in args_list]
        if not speculative:
            return [f.result() for f in futures]
        return self._map_speculative(fn, futures, straggler_factor)

    def _map_speculative(self, fn, futures, factor):
        """Re-submit laggards once >60% of tasks finished (backup tasks)."""
        results: dict = {}
        runtimes: List[float] = []
        speculated = set()
        while len(results) < len(futures):
            for i, f in enumerate(futures):
                if i in results:
                    continue
                if f.done():
                    results[i] = f.result()
                    rec = self.records[f.task_id]
                    if rec.runtime_s is not None:
                        runtimes.append(rec.runtime_s)
            if runtimes and len(results) >= 0.6 * len(futures):
                median = sorted(runtimes)[len(runtimes) // 2]
                for i, f in enumerate(futures):
                    if i in results or i in speculated:
                        continue
                    rec = self.records[f.task_id]
                    if rec.started is None:
                        rec.started = read_task_started(self.store_root, f.task_id)
                    if rec.started is None:
                        # still queued behind a full worker pool — a backup
                        # submission would just join the same queue; only a
                        # task that has actually STARTED can be a straggler
                        continue
                    running = time.time() - rec.started
                    if running > factor * max(median, 1e-3):
                        # args were uploaded (or content-addressed) at first
                        # submission; reuse those refs instead of re-uploading
                        arg_refs = self.records[f.task_id].arg_refs
                        f.add_speculative(
                            self.backend.submit(self.store_root, fn, arg_refs, f.task_id)
                        )
                        self.records[f.task_id].speculated = True
                        speculated.add(i)
            time.sleep(0.005)
        return [results[i] for i in range(len(futures))]

    # -- accounting ----------------------------------------------------------
    def _record_finish(self, task_id: int, payload: dict):
        rec = self.records.get(task_id)
        if rec is not None and rec.runtime_s is None:
            rec.runtime_s = payload["runtime_s"]
            rec.started = payload.get("started_at", rec.started)

    def cost_report(self) -> dict:
        """$ cost model per the paper: core-hours x VM price (spot discount)."""
        price = VM_PRICES.get(self.vm_type, 1.0) * (SPOT_DISCOUNT if self.spot else 1.0)
        runtimes = [r.runtime_s for r in self.records.values() if r.runtime_s]
        total_hours = sum(runtimes) / 3600.0
        return {
            "tasks": len(self.records),
            "vm_type": self.vm_type,
            "spot": self.spot,
            "task_hours": total_hours,
            "usd": total_hours * price,
            "mean_task_s": sum(runtimes) / max(len(runtimes), 1),
            "speculated": sum(1 for r in self.records.values() if r.speculated),
            "mean_submit_s": sum(self.submit_times) / max(len(self.submit_times), 1),
        }

    def shutdown(self):
        self.backend.shutdown()
