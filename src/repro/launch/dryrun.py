import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
import argparse
import dataclasses
import functools
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.tree import tree_params
from repro.configs import (
    ARCH_IDS,
    FNO_IDS,
    LM_SHAPES,
    cell_supported,
    get_arch,
    get_fno,
    get_fno_model_axes,
    get_shape,
    input_specs,
)
from repro.core import fno as fno_lib
from repro.launch import hlo_analysis
from repro.launch.mesh import dp_axes_for, make_pencil_mesh, make_production_mesh
from repro.models import transformer as tf_lib
from repro.models import whisper as wh_lib
from repro.models.policy import ParallelPolicy
from repro.train import AdamWConfig, init_opt_state, make_train_step
from repro.train.optimizer import opt_state_specs

"""Multi-pod AOT dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * compiled.memory_analysis()  -> per-device bytes (proves it fits)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes accessed
  * parsed collective traffic   -> bytes on the ICI wire per device
  * analytic MODEL_FLOPS        -> 6·N·D (train) or 2·N·D (serve)
EXPERIMENTS.md §Dry-run / §Roofline are generated from these artifacts.
"""


def _safe(spec: P, shape, mesh) -> P:
    """Drop axes that don't divide the dim (e.g. batch 1 at long_500k)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, ax in zip(shape, dims):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if d % size == 0 else None)
    return P(*out)


def _ns(mesh, spec_tree, abstract_tree):
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, _safe(s if isinstance(s, P) else P(), a.shape, mesh)),
        spec_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Cell builders: return (jitted_fn, example_args) ready for .lower().
# ---------------------------------------------------------------------------

def build_lm_cell(arch_id: str, shape_name: str, mesh, *, seq_shard=False, moe_a2a=True, kv_quant=False):
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    dp = dp_axes_for(mesh)
    policy = ParallelPolicy(
        mesh=mesh, dp_axes=dp, model_axis="model", seq_shard=seq_shard,
        moe_a2a=moe_a2a, remat=True, unroll_decode=True, kv_quant=kv_quant,
    )
    key = jax.random.PRNGKey(0)
    is_whisper = cfg.family == "encdec"

    if is_whisper:
        abstract_params = jax.eval_shape(functools.partial(wh_lib.init_whisper_params, cfg=cfg), key)
        p_specs = wh_lib.whisper_param_specs(cfg, policy)
    else:
        abstract_params = jax.eval_shape(functools.partial(tf_lib.init_lm_params, cfg=cfg), key)
        p_specs = tf_lib.param_specs(cfg, policy)
    shape_cfg = get_shape(shape_name)
    if shape_cfg.kind != "train":
        # Serving runs on bf16 weights (the f32 master copies live in the
        # training job, not the server).
        abstract_params = jax.tree.map(
            lambda t: jax.ShapeDtypeStruct(
                t.shape, jnp.bfloat16 if t.dtype == jnp.float32 else t.dtype
            ),
            abstract_params,
        )
    params_sh = _ns(mesh, p_specs, abstract_params)

    ins = input_specs(cfg, shape)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        loss_fn = (
            (lambda p, batch: wh_lib.whisper_loss(p, batch, cfg, policy))
            if is_whisper
            else (lambda p, batch: tf_lib.lm_loss(p, batch, cfg, policy))
        )
        step = make_train_step(loss_fn, AdamWConfig(lr=3e-4, weight_decay=0.1))
        abstract_opt = jax.eval_shape(init_opt_state, abstract_params)
        o_specs = opt_state_specs(p_specs, abstract_params, mesh, dp, zero1=True)
        opt_sh = _ns(mesh, o_specs, abstract_opt)
        batch_specs = {"tokens": P(dp, None), "targets": P(dp, None)}
        if is_whisper:
            batch_specs["frames"] = P(dp, None, None)
        batch_sh = _ns(mesh, batch_specs, ins)
        jitted = jax.jit(
            step,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        return jitted, (abstract_params, abstract_opt, ins), cfg

    if shape.kind == "prefill":
        if is_whisper:
            fn = lambda p, tokens, frames: wh_lib.whisper_prefill(p, tokens, frames, cfg, policy)
            args_sh = (params_sh, NamedSharding(mesh, _safe(P(dp, None), (b, s), mesh)),
                       NamedSharding(mesh, _safe(P(dp, None, None), (b, cfg.encoder.frames, cfg.d_model), mesh)))
            args = (abstract_params, ins["tokens"], ins["frames"])
        else:
            fn = lambda p, tokens: tf_lib.lm_prefill(p, tokens, cfg, policy)
            args_sh = (params_sh, NamedSharding(mesh, _safe(P(dp, None), (b, s), mesh)))
            args = (abstract_params, ins["tokens"])
        jitted = jax.jit(fn, in_shardings=args_sh)
        return jitted, args, cfg

    # decode: one token against a cache of length seq_len
    if is_whisper:
        abstract_cache = jax.eval_shape(lambda: wh_lib.init_whisper_cache(cfg, b, s))
        c_specs = {
            "self": {"k": P(None, dp, None, None, None), "v": P(None, dp, None, None, None)},
            "cross_k": P(None, dp, None, None, None),
            "cross_v": P(None, dp, None, None, None),
        }
        fn = lambda p, t, c, i: wh_lib.whisper_decode_step(p, t, c, i, cfg, policy)
    else:
        abstract_cache = jax.eval_shape(lambda: tf_lib.init_cache(cfg, b, s, policy=policy))
        c_specs = tf_lib.cache_specs(cfg, policy)
        fn = lambda p, t, c, i: tf_lib.lm_decode_step(p, t, c, i, cfg, policy)
    cache_sh = _ns(mesh, c_specs, abstract_cache)
    tok_sh = NamedSharding(mesh, _safe(P(dp, None), (b, 1), mesh))
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, tok_sh, cache_sh, NamedSharding(mesh, P())),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    args = (
        abstract_params,
        jax.ShapeDtypeStruct((b, 1), jnp.int32),
        abstract_cache,
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return jitted, args, cfg


def build_fno_cell(fno_id: str, shape_name: str, mesh, *, variant: str = "paper", fno_dtype=None):
    cfg, shapes = get_fno(fno_id)
    if fno_dtype is not None:
        cfg = dataclasses.replace(cfg, dtype=fno_dtype)
    shape = {name: (bsz, kind) for name, bsz, kind in shapes}[shape_name]
    bsz, kind = shape
    model_axis, pencil = get_fno_model_axes(fno_id)
    if isinstance(model_axis, tuple):
        # Pencil config: re-carve the production device pool into a
        # ("data", "mx", "my") mesh of the same total size so the lowered
        # HLO actually contains the 2-D schedule's two all-to-alls.
        px, py = pencil
        if mesh.size % (px * py):
            raise ValueError(
                f"{fno_id}: pencil {pencil} does not divide mesh size {mesh.size}"
            )
        mesh = make_pencil_mesh(mesh.size // (px * py), px, py)
        if variant not in ("paper", "eager"):
            # grady31 has no 2-D schedule; make the substitution visible so
            # a --variant grady31 sweep knows this cell has no baseline.
            print(f"NOTE {fno_id}: variant {variant!r} has no 2-D schedule; "
                  "lowering 'paper' instead")
            variant = "paper"
    dp = dp_axes_for(mesh)
    key = jax.random.PRNGKey(0)
    abstract_params = jax.eval_shape(functools.partial(fno_lib.init_params, cfg=cfg), key)
    p_specs = fno_lib.param_specs(mesh, model_axis)
    params_sh = _ns(mesh, p_specs, abstract_params)
    fwd = fno_lib.make_dist_forward(mesh, cfg, dp_axes=dp, model_axis=model_axis, variant=variant)
    nx, ny, nz, nt = cfg.grid
    x_spec = fno_lib.input_spec(dp, model_axis)
    x_abs = jax.ShapeDtypeStruct((bsz, cfg.in_channels, nx, ny, nz, nt), jnp.float32)
    y_abs = jax.ShapeDtypeStruct((bsz, cfg.out_channels, nx, ny, nz, nt), jnp.float32)
    x_sh = NamedSharding(mesh, _safe(x_spec, x_abs.shape, mesh))

    cell_meta = {"mesh": mesh, "variant": variant}
    if kind == "infer":
        jitted = jax.jit(fwd, in_shardings=(params_sh, x_sh), out_shardings=x_sh)
        return jitted, (abstract_params, x_abs), cfg, cell_meta

    def loss_fn(p, batch):
        pred = fwd(p, batch["x"])
        return fno_lib.mse_loss(pred, batch["y"]), {}

    step = make_train_step(loss_fn, AdamWConfig(lr=1e-3))
    abstract_opt = jax.eval_shape(init_opt_state, abstract_params)
    o_specs = opt_state_specs(p_specs, abstract_params, mesh, dp, zero1=True)
    opt_sh = _ns(mesh, o_specs, abstract_opt)
    batch_sh = {"x": x_sh, "y": x_sh}
    jitted = jax.jit(
        step,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, None),
        donate_argnums=(0, 1),
    )
    return jitted, (abstract_params, abstract_opt, {"x": x_abs, "y": y_abs}), cfg, cell_meta


# ---------------------------------------------------------------------------
# Lower + compile + analyse one cell.
# ---------------------------------------------------------------------------

def model_flops_lm(cfg, shape) -> float:
    n_active = cfg.approx_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def model_flops_fno(cfg: fno_lib.FNOConfig, batch: int, kind: str) -> float:
    """Analytic forward FLOPs: spectral einsum + bypass + enc/dec + FFTs."""
    import math

    nx, ny, nz, nt = cfg.grid
    grid_pts = nx * ny * nz * nt
    k_modes = 1
    for m in cfg.mode_shape:
        k_modes *= m
    w = cfg.width
    spectral = 8.0 * w * w * k_modes          # complex MAC = 8 real flops
    bypass = 2.0 * w * w * grid_pts
    fft = 2 * 5.0 * grid_pts * w * (math.log2(nx) + math.log2(ny) + math.log2(nz) + math.log2(nt))
    per_block = spectral + bypass + fft
    enc = 2.0 * cfg.in_channels * w * grid_pts
    dec = 2.0 * w * cfg.decoder_dim * grid_pts + 2.0 * cfg.decoder_dim * cfg.out_channels * grid_pts
    fwd = batch * (enc + dec + cfg.n_blocks * per_block)
    return 3.0 * fwd if kind == "train" else fwd


def run_cell(
    kind: str,
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool,
    out_dir: Optional[str],
    variant: str = "paper",
    seq_shard: bool = False,
    fno_dtype=None,
    kv_quant: bool = False,
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    if kind == "fno":
        jitted, args, cfg, cell_meta = build_fno_cell(arch_id, shape_name, mesh, variant=variant, fno_dtype=fno_dtype)
        # Pencil configs re-carve the mesh and may coerce the variant;
        # record what was actually lowered, not what was requested.
        mesh, variant = cell_meta["mesh"], cell_meta["variant"]
        n_dev = mesh.size
        shape_kind = dict((n, k) for n, _, k in get_fno(arch_id)[1])[shape_name]
        mf = model_flops_fno(cfg, [b for n, b, _ in get_fno(arch_id)[1] if n == shape_name][0], shape_kind)
        n_params = tree_params(jax.eval_shape(functools.partial(fno_lib.init_params, cfg=cfg), jax.random.PRNGKey(0)))
    else:
        jitted, args, cfg = build_lm_cell(arch_id, shape_name, mesh, seq_shard=seq_shard, kv_quant=kv_quant)
        mf = model_flops_lm(cfg, get_shape(shape_name))
        n_params = cfg.approx_params()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = hlo_analysis.collect_collectives(hlo, n_devices_default=n_dev)
    compute = hlo_analysis.collect_compute(hlo)

    artifact = {
        "arch": arch_id,
        "shape": shape_name,
        "kind": kind,
        "variant": variant,
        "mesh": {"shape": list(mesh.devices.shape), "axes": list(mesh.axis_names), "devices": n_dev},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_params": int(n_params),
        "model_flops": mf,
        # cost_analysis counts while bodies once; *_loopaware weights loop
        # bodies by their trip counts (see hlo_analysis.collect_compute).
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "hlo_flops_loopaware": compute["flops"],
        "hlo_bytes_est": compute["bytes_est"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # CPU buffer assignment performs no reuse: temp is the SUM of
            # all temporaries, an upper bound on TPU live memory.
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_per_device": hlo_analysis.peak_memory_bytes(mem),
            "resident_bytes": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "collectives": colls.to_dict(),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "multipod" if multi_pod else "pod"
        if variant != "paper":
            suffix += f"_{variant}"
        if not seq_shard:
            suffix += "_nosp"
        path = os.path.join(out_dir, f"{arch_id}_{shape_name}_{suffix}.json")
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1)
        artifact["path"] = path
    return artifact


def iter_cells():
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        for shape in LM_SHAPES:
            ok, why = cell_supported(cfg, shape)
            if ok:
                yield ("lm", arch_id, shape.name)
    for fno_id in FNO_IDS:
        _, shapes = get_fno(fno_id)
        for name, _, _ in shapes:
            yield ("fno", fno_id, name)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (or fno id)")
    ap.add_argument("--shape", help="shape name")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every supported cell")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--variant", default="paper", choices=("paper", "grady31"))
    ap.add_argument(
        "--seq-shard", action=argparse.BooleanOptionalAction, default=True,
        help="Megatron-SP activation sharding (default on; --no-seq-shard "
        "lowers the seq-replicated baseline for §Perf comparisons)",
    )
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    args = ap.parse_args()

    if args.list:
        for kind, arch, shape in iter_cells():
            print(f"{kind:4s} {arch:24s} {shape}")
        return

    cells = []
    if args.all:
        cells = list(iter_cells())
    else:
        kind = "fno" if args.arch in FNO_IDS else "lm"
        if kind == "lm":
            ok, why = cell_supported(get_arch(args.arch), get_shape(args.shape))
            if not ok:
                print(f"SKIP {args.arch} x {args.shape}: {why}")
                return
        cells = [(kind, args.arch, args.shape)]

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    failures = []
    for kind, arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} [{'2x16x16' if mp else '16x16'}]"
            try:
                art = run_cell(
                    kind, arch, shape, multi_pod=mp, out_dir=args.out_dir,
                    variant=args.variant, seq_shard=args.seq_shard,
                )
                print(
                    f"OK  {tag:60s} compile={art['compile_s']:7.1f}s "
                    f"flops={art['hlo_flops']:.3e} coll={art['collectives']['total_bytes']:.3e}B "
                    f"peak={art['memory']['peak_per_device']/2**30:.2f}GiB"
                )
            except Exception as e:  # noqa: BLE001 — report and continue the sweep
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {[t for t, _ in failures]}")


if __name__ == "__main__":
    main()
