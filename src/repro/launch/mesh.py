"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

from repro.common import compat
from repro.common.constants import (
    MULTIPOD_MESH_AXES,
    MULTIPOD_MESH_SHAPE,
    POD_MESH_AXES,
    POD_MESH_SHAPE,
)

# Axis names understood as model-parallel: "model" (1-D, paper Alg. 2) and
# the ("mx", "my") pair (2-D pencil decomposition).
MODEL_AXIS_NAMES = ("model", "mx", "my")


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_MESH_SHAPE if multi_pod else POD_MESH_SHAPE
    axes = MULTIPOD_MESH_AXES if multi_pod else POD_MESH_AXES
    return compat.make_mesh(shape, axes)


def make_pencil_mesh(n_data: int, n_x: int, n_y: int):
    """("data", "mx", "my") mesh for the 2-D pencil-decomposed FNO."""
    return compat.make_mesh((n_data, n_x, n_y), ("data", "mx", "my"))


def dp_axes_for(mesh) -> tuple:
    """Data-parallel axes: every axis that is not a model axis."""
    return tuple(a for a in mesh.axis_names if a not in MODEL_AXIS_NAMES)
