"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax

from repro.common.constants import (
    MULTIPOD_MESH_AXES,
    MULTIPOD_MESH_SHAPE,
    POD_MESH_AXES,
    POD_MESH_SHAPE,
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_MESH_SHAPE if multi_pod else POD_MESH_SHAPE
    axes = MULTIPOD_MESH_AXES if multi_pod else POD_MESH_AXES
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def dp_axes_for(mesh) -> tuple:
    """Data-parallel axes: every axis that is not the model axis."""
    return tuple(a for a in mesh.axis_names if a != "model")
