"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

from repro.common import compat
from repro.common.constants import (
    MULTIPOD_MESH_AXES,
    MULTIPOD_MESH_SHAPE,
    POD_MESH_AXES,
    POD_MESH_SHAPE,
)

# Axis names understood as model-parallel: "model" (1-D, paper Alg. 2) and
# the ("mx", "my") pair (2-D pencil decomposition).
MODEL_AXIS_NAMES = ("model", "mx", "my")


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_MESH_SHAPE if multi_pod else POD_MESH_SHAPE
    axes = MULTIPOD_MESH_AXES if multi_pod else POD_MESH_AXES
    return compat.make_mesh(shape, axes)


def make_pencil_mesh(n_data: int, n_x: int, n_y: int):
    """("data", "mx", "my") mesh for the 2-D pencil-decomposed FNO."""
    return compat.make_mesh((n_data, n_x, n_y), ("data", "mx", "my"))


def build_fno_mesh(n_devices: int, model_shards):
    """(mesh, model_axis, n_model) from a device count and --model-shards:
    data axis x 0/1/2 model axes. One shard value P decomposes the solution
    along x (paper Alg. 2, "model" axis); two values PX PY use the 2-D
    pencil decomposition on ("mx", "my"). Shared by the training and
    serving drivers so both sides agree on the mesh for a checkpoint."""
    from repro.core.partition import make_mesh

    model_shards = tuple(model_shards)
    if len(model_shards) > 2:
        raise ValueError(
            f"model shards take 1 (x-decomposition) or 2 (x,y pencil) "
            f"values, got {len(model_shards)}: {model_shards}"
        )
    n_model = 1
    for s in model_shards:
        n_model *= s
    if n_devices % n_model:
        raise ValueError(
            f"{n_devices} devices not divisible by {n_model} model shards"
        )
    n_dp = n_devices // n_model
    if n_model == 1:
        return make_mesh((n_dp,), ("data",)), None, 1
    if len(model_shards) == 1:
        return (
            make_mesh((n_dp, model_shards[0]), ("data", "model")),
            "model",
            n_model,
        )
    return make_pencil_mesh(n_dp, *model_shards), ("mx", "my"), n_model


def dp_axes_for(mesh) -> tuple:
    """Data-parallel axes: every axis that is not a model axis."""
    return tuple(a for a in mesh.axis_names if a not in MODEL_AXIS_NAMES)
