"""End-to-end training driver (CPU-sized by default; mesh-ready).

Two modes:
  fno — train the paper's FNO surrogate on simulated data (from a chunked
        ArrayStore produced by the cloud datagen layer, or synthetic);
  lm  — train a reduced-config assigned architecture on synthetic tokens.

Fault tolerance is on by default: periodic sharded checkpoints, restart
from the latest on crash (--inject-fault demonstrates it), straggler
watchdog. ``--devices N`` spawns N host devices for a real data-parallel
mesh on CPU.
"""
import os
import sys

if "--devices" in sys.argv:  # must precede any jax import
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import FNOConfig, fno_forward, init_params, mse_loss
from repro.models import init_lm_params, lm_loss
from repro.models.policy import LOCAL
from repro.train import AdamWConfig, init_opt_state, make_train_step, warmup_cosine
from repro.train.fault import FaultInjector, run_supervised


def fno_batch_iter(x_all, y_all, batch):
    def it(step):
        n = x_all.shape[0]
        idx = [(step * batch + j) % n for j in range(batch)]
        return {"x": x_all[np.asarray(idx)], "y": y_all[np.asarray(idx)]}

    return it


def synthetic_fno_data(cfg: FNOConfig, n: int, seed: int = 0):
    """Band-limited random fields (stand-in when no simulated store given)."""
    key = jax.random.PRNGKey(seed)
    nx, ny, nz, nt = cfg.grid
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, cfg.in_channels, nx, ny, nz, nt), jnp.float32)
    # target: smoothed nonlinear transform (learnable mapping)
    y = jnp.tanh(jnp.roll(x, 1, axis=2) + 0.5 * jnp.roll(x, 2, axis=3)) * 0.5
    return np.asarray(x), np.asarray(y[:, : cfg.out_channels])


def load_store_data(x_store_dir, y_store_dir):
    from repro.data.store import ArrayStore

    xs = ArrayStore.open(x_store_dir)
    ys = ArrayStore.open(y_store_dir)
    n = xs.n_complete()
    x = np.stack([xs.read_chunk((i,) + (0,) * (len(xs.shape) - 1))[0] for i in range(n)])
    y = np.stack([ys.read_chunk((i,) + (0,) * (len(ys.shape) - 1))[0] for i in range(n)])
    if x.ndim == len(xs.shape) - 1 + 1:
        x = x[:, None]  # add channel dim
    if x.ndim == 5:
        x = x[:, None]
    if y.ndim == 5:
        y = y[:, None]
    return x.astype(np.float32), y.astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("fno", "lm"), default="fno")
    ap.add_argument("--arch", default="gemma-7b", help="lm mode: assigned arch id")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--inject-fault", type=int, default=None, help="fail once at this step")
    ap.add_argument("--x-store", default=None)
    ap.add_argument("--y-store", default=None)
    ap.add_argument("--grid", type=int, nargs=4, default=(16, 16, 8, 8))
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--n-data", type=int, default=16)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument(
        "--model-shards", type=int, nargs="+", default=[1],
        help="fno mode: model-parallel shards. One value P shards the "
        "solution along x (paper Alg. 2); two values PX PY use the 2-D "
        "pencil decomposition on a ('mx','my') mesh.",
    )
    args = ap.parse_args()

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, warmup=10, total=args.steps), weight_decay=0.0
    )

    if args.mode == "fno":
        if args.x_store:
            x_all, y_all = load_store_data(args.x_store, args.y_store)
            grid = x_all.shape[-4:]
        else:
            grid = tuple(args.grid)
            x_all = y_all = None
        cfg = FNOConfig(
            grid=grid,
            modes=tuple(max(2, g // 4) for g in grid),
            width=args.width,
            n_blocks=4,
            decoder_dim=32,
        )
        if x_all is None:
            x_all, y_all = synthetic_fno_data(cfg, args.n_data)

        model_shards = tuple(args.model_shards)
        if len(model_shards) > 2:
            raise SystemExit(
                f"--model-shards takes 1 (x-decomposition) or 2 (x,y pencil) "
                f"values, got {len(model_shards)}: {model_shards}"
            )
        n_model = 1
        for s in model_shards:
            n_model *= s
        if n_model > 1:
            from repro.core import make_dist_forward
            from repro.launch.mesh import make_pencil_mesh
            from repro.core.partition import make_mesh as _make_mesh

            if args.devices % n_model:
                raise SystemExit(
                    f"--devices {args.devices} not divisible by "
                    f"{n_model} model shards"
                )
            n_dp = args.devices // n_model
            if len(model_shards) == 1:
                mesh = _make_mesh((n_dp, model_shards[0]), ("data", "model"))
                model_axis = "model"
            else:
                mesh = make_pencil_mesh(n_dp, *model_shards)
                model_axis = ("mx", "my")
            dist_fwd = make_dist_forward(
                mesh, cfg, dp_axes=("data",), model_axis=model_axis
            )

            def loss_fn(params, batch):
                pred = dist_fwd(params, batch["x"])
                return mse_loss(pred, batch["y"]), {}

        else:

            def loss_fn(params, batch):
                pred = fno_forward(params, batch["x"], cfg)
                return mse_loss(pred, batch["y"]), {}

        init_fn = functools.partial(init_params, cfg=cfg)
        batches = fno_batch_iter(x_all, y_all, args.batch)
    else:
        cfg = reduced(get_arch(args.arch))
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, cfg.vocab, size=(args.n_data, args.batch, 33), dtype=np.int32)

        def loss_fn(params, batch):
            loss, m = lm_loss(params, batch, cfg, LOCAL)
            return loss, m

        def batches(step):
            t = tokens[step % args.n_data]
            return {"tokens": jnp.asarray(t[:, :-1]), "targets": jnp.asarray(t[:, 1:])}

        init_fn = functools.partial(init_lm_params, cfg=cfg)

    step_fn = make_train_step(loss_fn, opt_cfg, grad_accum=args.grad_accum)
    jit_step = jax.jit(step_fn)

    def init_state():
        params = init_fn(jax.random.PRNGKey(0))
        return {"params": params, "opt": init_opt_state(params)}

    def train_step(state, batch):
        params, opt, metrics = jit_step(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, metrics

    injector = FaultInjector([args.inject_fault]) if args.inject_fault is not None else None
    result = run_supervised(
        init_state=init_state,
        train_step=train_step,
        batch_iter=batches,
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        save_every=args.save_every,
        injector=injector,
        async_save=True,
    )
    first = result.metrics_log[0][1]["loss"] if result.metrics_log else float("nan")
    last = result.metrics_log[-1][1]["loss"] if result.metrics_log else float("nan")
    print(
        f"done: steps={result.final_step} failures={result.failures} "
        f"restores={result.restores} loss {first:.4f} -> {last:.4f} "
        f"stragglers={len(result.straggler_steps)}"
    )
    return result


if __name__ == "__main__":
    main()
