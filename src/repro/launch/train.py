"""End-to-end training driver (CPU-sized by default; mesh-ready).

Two modes:
  fno — train the paper's FNO surrogate on simulated data (from a chunked
        ArrayStore produced by the cloud datagen layer, or synthetic);
  lm  — train a reduced-config assigned architecture on synthetic tokens.

The fno path is fully sharded end to end: batches come from the
``ShardedDatasetLoader`` (each device reads only the store chunks under its
``(mx, my)`` pencil and its slice of the batch dim, prefetched on a
background thread) and the jitted step goes through ``shard_train_step``
with explicit batch/param shardings on the data x model mesh — the same
PartitionSpecs on both sides, so no resharding happens at the jit boundary.

Fault tolerance is on by default: periodic sharded checkpoints, restart
from the latest on crash (--inject-fault demonstrates it), straggler
watchdog. ``--devices N`` spawns N host devices for a real data-parallel
mesh on CPU.
"""
import os
import sys

# must precede any jax import (repro.launch.devices never imports jax)
from repro.launch.devices import apply_device_flag

apply_device_flag(sys.argv)

import argparse
import functools
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import FNOConfig, forward_and_specs, init_params, mse_loss
from repro.launch.devices import sniff_devices  # noqa: F401  (re-export)
from repro.launch.mesh import build_fno_mesh
from repro.models import init_lm_params, lm_loss
from repro.models.policy import LOCAL
from repro.train import AdamWConfig, init_opt_state, make_train_step, warmup_cosine
from repro.train.fault import FaultInjector, run_supervised
from repro.train.train_loop import shard_train_step


def start_online_datagen(args):
    """Spawn ``run_datagen`` in a background thread (the paper's 'simulate
    in advance' cost removed: training overlaps it). Returns
    ``(thread, err_holder)``; the holder carries any datagen exception so
    the trainer fails loudly instead of stalling forever."""
    from repro.launch.datagen import build_parser, run_datagen

    if args.x_store:
        root = os.path.dirname(os.path.abspath(args.x_store))
        if (
            os.path.dirname(os.path.abspath(args.y_store)) != root
            or os.path.basename(os.path.abspath(args.x_store)) != "x"
            or os.path.basename(os.path.abspath(args.y_store)) != "y"
        ):
            raise SystemExit(
                "--online: stores must be <root>/x and <root>/y "
                "(datagen's layout); or pass --out <root> instead"
            )
    elif args.out:
        root = args.out
        args.x_store = os.path.join(root, "x")
        args.y_store = os.path.join(root, "y")
    else:
        raise SystemExit("--online needs --out (or --x-store/--y-store)")
    nx, ny, nz, nt = args.grid
    # same pre-parsed argv contract as the CLI (and the same --devices
    # parsing caveat does not apply: datagen never touches jax/XLA flags)
    dg_args = build_parser().parse_args([
        "--pde", args.pde, "--n", str(args.n_data),
        "--grid", str(nx), str(ny), str(nz), "--nt", str(nt),
        "--out", root, "--backend", args.datagen_backend,
        "--workers", str(args.datagen_workers),
        "--chunks-xy", str(args.chunks_xy[0]), str(args.chunks_xy[1]),
        "--stats-every", str(max(1, min(args.batch, 4))),
        "--seed", str(args.seed), "--resume",
    ])
    err = []

    def _run():
        try:
            run_datagen(dg_args)
        except BaseException as e:  # noqa: BLE001 — surfaced by the waiters
            err.append(e)

    th = threading.Thread(target=_run, name="online-datagen", daemon=True)
    th.start()
    return th, err


def _wait_online(path: str, err: list, timeout: float, need_stats: bool):
    """Block until the store exists (and, if asked, carries normalization
    stats from the incremental Welford pass); returns the opened store."""
    from repro.data import ArrayStore

    deadline = time.monotonic() + timeout
    while True:
        if os.path.exists(os.path.join(path, "meta.json")):
            store = ArrayStore.open(path)
            if not need_stats or "stats" in store.meta:
                return store
        if err:
            raise RuntimeError("online datagen failed") from err[0]
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"--online: store {path} "
                f"{'has no stats' if need_stats else 'never appeared'} "
                f"after {timeout}s"
            )
        time.sleep(0.05)


def synthetic_fno_data(cfg: FNOConfig, n: int, seed: int = 0):
    """Band-limited random fields (stand-in when no simulated store given)."""
    key = jax.random.PRNGKey(seed)
    nx, ny, nz, nt = cfg.grid
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, cfg.in_channels, nx, ny, nz, nt), jnp.float32)
    # target: smoothed nonlinear transform (learnable mapping)
    y = jnp.tanh(jnp.roll(x, 1, axis=2) + 0.5 * jnp.roll(x, 2, axis=3)) * 0.5
    return np.asarray(x), np.asarray(y[:, : cfg.out_channels])


def write_fno_serving_config(ckpt_dir: str, cfg: FNOConfig, args, x_src, y_src,
                             normalized) -> None:
    """Persist the serving contract next to the checkpoints: architecture,
    model-shard layout, and a snapshot of the normalization stats/kind the
    run trained with — everything ``FNORunner.from_checkpoint`` needs to
    serve the surrogate in physical units without the original stores."""
    def stats_of(src):
        return (getattr(src, "meta", None) or {}).get("stats")

    def kind_of(src):
        return (getattr(src, "meta", None) or {}).get("normalizer", "meanstd")

    os.makedirs(ckpt_dir, exist_ok=True)
    payload = {
        "grid": list(cfg.grid),
        "modes": list(cfg.modes),
        "width": cfg.width,
        "in_channels": cfg.in_channels,
        "out_channels": cfg.out_channels,
        "n_blocks": cfg.n_blocks,
        "decoder_dim": cfg.decoder_dim,
        "model_shards": list(args.model_shards),
        "use_pallas": cfg.use_pallas,
        "comm_chunks": cfg.comm_chunks,
        "normalized": list(normalized),
        "normalizer": kind_of(x_src),
        "x_stats": stats_of(x_src),
        "y_stats": stats_of(y_src),
    }
    tmp = os.path.join(ckpt_dir, f"fno_config.json.tmp{os.getpid()}")
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.rename(tmp, os.path.join(ckpt_dir, "fno_config.json"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("fno", "lm"), default="fno")
    ap.add_argument("--arch", default="gemma-7b", help="lm mode: assigned arch id")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--inject-fault", type=int, default=None, help="fail once at this step")
    ap.add_argument("--x-store", default=None)
    ap.add_argument("--y-store", default=None)
    ap.add_argument("--online", action="store_true",
                    help="fno mode: spawn datagen in the background and "
                    "start training from the store's visible sample prefix "
                    "(Meyer-et-al streaming) instead of simulate-then-train")
    ap.add_argument("--out", default=None,
                    help="--online: dataset root (writes <out>/x, <out>/y); "
                    "alternative to --x-store/--y-store")
    ap.add_argument("--pde", choices=("two_phase", "navier_stokes"),
                    default="two_phase", help="--online: PDE to simulate")
    ap.add_argument("--datagen-workers", type=int, default=4)
    ap.add_argument("--datagen-backend", choices=("process", "thread"),
                    default="thread")
    ap.add_argument("--chunks-xy", type=int, nargs=2, default=(2, 2),
                    metavar=("CX", "CY"), help="--online: store chunking")
    ap.add_argument("--online-timeout", type=float, default=600.0,
                    help="--online: max seconds to wait for the simulator "
                    "(first samples, stats, per-step back-pressure)")
    ap.add_argument("--no-normalize", action="store_true",
                    help="skip input normalization from the store's stats")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the loader's background prefetch thread")
    ap.add_argument("--no-shuffle", action="store_true")
    ap.add_argument("--grid", type=int, nargs=4, default=(16, 16, 8, 8))
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--n-data", type=int, default=16)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--model-shards", type=int, nargs="+", default=[1],
        help="fno mode: model-parallel shards. One value P shards the "
        "solution along x (paper Alg. 2); two values PX PY use the 2-D "
        "pencil decomposition on a ('mx','my') mesh.",
    )
    ap.add_argument(
        "--use-pallas", action="store_true",
        help="fno mode: fused Pallas spectral path (truncate + channel-mix "
        "+ pad in one kernel pass; interpret mode off-TPU). Equivalence-"
        "gated vs the unfused path; persisted into fno_config.json so "
        "serving defaults to the same path.",
    )
    ap.add_argument(
        "--comm-chunks", type=int, default=1,
        help="fno mode: channel-chunk the distributed FFT pipelines so "
        "each chunk's all-to-all overlaps the next chunk's FFTs "
        "(bit-identical; needs the latency-hiding scheduler flags).",
    )
    args = ap.parse_args()

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, warmup=10, total=args.steps), weight_decay=0.0
    )
    loader = None
    schedule = None
    dg_thread = dg_err = None
    if args.online and args.mode != "fno":
        raise SystemExit("--online is an fno-mode flag")

    if args.mode == "fno":
        from repro.data import (
            ArrayStore, NdArraySource, ShardedDatasetLoader, StreamingSchedule,
        )

        if args.online:
            dg_thread, dg_err = start_online_datagen(args)
            x_src = _wait_online(
                args.x_store, dg_err, args.online_timeout,
                need_stats=not args.no_normalize,
            )
            y_src = _wait_online(
                args.y_store, dg_err, args.online_timeout, need_stats=False
            )
        else:
            if bool(args.x_store) != bool(args.y_store):
                raise SystemExit("--x-store and --y-store must be given together")
            if args.x_store:
                x_src = ArrayStore.open(args.x_store)
                y_src = ArrayStore.open(args.y_store)
            else:
                x_src = y_src = None
        if x_src is not None:
            grid = tuple(x_src.shape[-4:])
            in_ch, out_ch = x_src.shape[1], y_src.shape[1]
        else:
            grid = tuple(args.grid)
            in_ch = out_ch = 1
        cfg = FNOConfig(
            grid=grid,
            modes=tuple(max(2, g // 4) for g in grid),
            width=args.width,
            in_channels=in_ch,
            out_channels=out_ch,
            n_blocks=4,
            decoder_dim=32,
            use_pallas=args.use_pallas,
            comm_chunks=args.comm_chunks,
        )
        if x_src is None:
            x_all, y_all = synthetic_fno_data(cfg, args.n_data)
            x_src, y_src = NdArraySource(x_all), NdArraySource(y_all)

        try:
            mesh, model_axis, n_model = build_fno_mesh(
                args.devices, args.model_shards
            )
        except ValueError as e:  # library error -> CLI-flag wording
            raise SystemExit(f"--devices/--model-shards: {e}") from None
        n_dp = mesh.shape["data"]
        if args.batch % n_dp:
            raise SystemExit(
                f"--batch {args.batch} not divisible by the data-parallel "
                f"size {n_dp} ({args.devices} devices / {n_model} model shards)"
            )
        # one source of truth for the model/data layout, shared with the
        # serving runner: the loader assembles batches with exactly the
        # specs the jitted step declares
        fwd, x_spec, p_specs = forward_and_specs(
            mesh, cfg, dp_axes=("data",), model_axis=model_axis
        )

        def loss_fn(params, batch):
            pred = fwd(params, batch["x"])
            return mse_loss(pred, batch["y"]), {}

        batch_specs = {"x": x_spec, "y": x_spec}
        init_fn = functools.partial(init_params, cfg=cfg)
        if args.online:
            # draw each batch from the complete-prefix watermark while
            # datagen is still writing; the per-step watermark log is
            # persisted next to the checkpoints so a restarted process
            # replays the exact same schedule (fault supervisor contract)
            os.makedirs(args.ckpt_dir, exist_ok=True)
            if not args.no_normalize:
                # datagen keeps rewriting meta.json stats as samples land;
                # snapshot the stats this run normalizes with so a restarted
                # process replays numerically identical batches, not just
                # the same sample ids
                snap = os.path.join(args.ckpt_dir, "stats_snapshot.json")
                if os.path.exists(snap):
                    with open(snap) as f:
                        x_src.meta["stats"] = json.load(f)
                else:
                    tmp = snap + f".tmp{os.getpid()}"
                    with open(tmp, "w") as f:
                        json.dump(x_src.meta["stats"], f)
                    os.rename(tmp, snap)
            schedule = StreamingSchedule(
                [x_src, y_src],
                args.batch,
                seed=args.seed,
                timeout=args.online_timeout,
                log_path=os.path.join(args.ckpt_dir, "watermarks.json"),
            )
        # persist the serving contract (arch + normalization snapshot —
        # AFTER the online path pinned its stats snapshot) so serve_pde.py /
        # FNORunner.from_checkpoint can load this run without the stores
        write_fno_serving_config(
            args.ckpt_dir, cfg, args, x_src, y_src,
            normalized=() if args.no_normalize else ("x",),
        )
        loader = ShardedDatasetLoader(
            {"x": x_src, "y": y_src},
            mesh,
            args.batch,
            batch_specs,
            seed=args.seed,
            shuffle=not args.no_shuffle,
            normalize=() if args.no_normalize else ("x",),
            prefetch=0 if args.no_prefetch else 2,
            schedule=schedule,
        )
        batches = loader.batch
    else:
        from repro.core.partition import make_mesh

        cfg = reduced(get_arch(args.arch))
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, cfg.vocab, size=(args.n_data, args.batch, 33), dtype=np.int32)

        def loss_fn(params, batch):
            loss, m = lm_loss(params, batch, cfg, LOCAL)
            return loss, m

        def batches(step):
            t = tokens[step % args.n_data]
            return {"tokens": jnp.asarray(t[:, :-1]), "targets": jnp.asarray(t[:, 1:])}

        init_fn = functools.partial(init_lm_params, cfg=cfg)
        if args.batch % args.devices:
            raise SystemExit(
                f"--batch {args.batch} not divisible by --devices {args.devices}"
            )
        mesh = make_mesh((args.devices,), ("data",))
        from jax.sharding import PartitionSpec as P

        abstract = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        p_specs = jax.tree.map(lambda _: P(), abstract)
        batch_specs = {"tokens": P("data"), "targets": P("data")}

    step_fn = make_train_step(loss_fn, opt_cfg, grad_accum=args.grad_accum)
    abstract_params = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    jit_step = shard_train_step(
        step_fn, mesh, p_specs, abstract_params, batch_specs, dp_axes=("data",)
    )

    def init_state():
        params = init_fn(jax.random.PRNGKey(0))
        return {"params": params, "opt": init_opt_state(params)}

    online_info = {}

    def train_step(state, batch):
        if schedule is not None and "first_n_complete" not in online_info:
            # the moment the first step launches: how much of the dataset
            # exists? < n proves simulation and training truly overlap
            online_info["first_visible"] = schedule.visible_now()
            online_info["first_n_complete"] = loader.sources["x"].n_complete()
        params, opt, metrics = jit_step(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, metrics

    injector = FaultInjector([args.inject_fault]) if args.inject_fault is not None else None
    try:
        result = run_supervised(
            init_state=init_state,
            train_step=train_step,
            batch_iter=batches,
            total_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            save_every=args.save_every,
            injector=injector,
            async_save=True,
        )
    finally:
        if loader is not None:
            loader.close()
    if dg_thread is not None:
        dg_thread.join()  # let the simulator finish/flush before reporting
        if dg_err:
            raise RuntimeError("online datagen failed") from dg_err[0]
    first = result.metrics_log[0][1]["loss"] if result.metrics_log else float("nan")
    last = result.metrics_log[-1][1]["loss"] if result.metrics_log else float("nan")
    print(
        f"done: steps={result.final_step} failures={result.failures} "
        f"restores={result.restores} loss {first:.3e} -> {last:.3e} "
        f"stragglers={len(result.straggler_steps)}"
    )
    if schedule is not None:
        n_total = loader.sources["x"].shape[0]
        sm = schedule.metrics()
        overlapped = online_info.get("first_n_complete", n_total) < n_total
        print(
            f"online: first step with {online_info.get('first_n_complete', '?')}"
            f"/{n_total} samples complete "
            f"(visible={online_info.get('first_visible', '?')}) "
            f"stalls={sm['stalls']} stall_s={sm['stall_s']} "
            f"overlap={overlapped}"
        )
    return result


if __name__ == "__main__":
    main()
