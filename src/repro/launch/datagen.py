"""Cloud datagen CLI: BatchPool simulations -> chunked ArrayStore + stats.

The paper's §V workflow, end to end: submit PDE simulations to the
clusterless batch pool (process workers standing in for Azure Batch VMs),
write every training pair into the chunked array store — spatially chunked
along x and y so each training shard later reads only its pencil — and
maintain a streaming Welford pass that merges each sample as it is written,
persisting per-channel normalization stats into the store's meta.json every
``--stats-every`` samples (so an online trainer can normalize long before
the dataset is finished; ``run_datagen`` is the library entry train.py's
``--online`` mode spawns in the background).

Writes are resumable and idempotent: chunk publishes are atomic, a sample
counts as done only when ALL its chunks exist, and a rerun simulates only
the missing samples (task args are derived deterministically from the
sample index, so a retry regenerates identical data).

    PYTHONPATH=src python -m repro.launch.datagen \
        --pde two_phase --n 8 --grid 16 8 8 --nt 4 --out /tmp/co2_ds
    PYTHONPATH=src python src/repro/launch/train.py --mode fno \
        --x-store /tmp/co2_ds/x --y-store /tmp/co2_ds/y \
        --devices 8 --model-shards 2 2
"""
from __future__ import annotations

import argparse
import os
from typing import List, Tuple

import numpy as np

from repro.cloud import BatchPool, LocalProcessBackend, ThreadBackend
from repro.data.store import ArrayStore


# -- streaming normalization stats ------------------------------------------

def merge_welford(state, data: np.ndarray, axis) -> tuple:
    """Merge a data block into a running (count, mean, M2, absmax)
    per-channel state (Chan et al. parallel update, plus a running max|x|
    for the paper's normalize-by-max scheme) — one chunk in memory at a
    time."""
    n_b = int(np.prod([data.shape[a] for a in axis])) or 1
    mean_b = data.mean(axis=axis, dtype=np.float64)
    m2_b = ((data.astype(np.float64) - np.expand_dims(mean_b, axis)) ** 2).sum(axis=axis)
    amax_b = np.abs(data).max(axis=axis).astype(np.float64)
    if state is None:
        return n_b, mean_b, m2_b, amax_b
    n_a, mean_a, m2_a, amax_a = state
    n = n_a + n_b
    delta = mean_b - mean_a
    mean = mean_a + delta * (n_b / n)
    m2 = m2_a + m2_b + delta ** 2 * (n_a * n_b / n)
    return n, mean, m2, np.maximum(amax_a, amax_b)


def merge_sample_welford(state, sample: np.ndarray) -> tuple:
    """Merge one full training sample ``[c, *spatial]`` into the running
    state — the unit of the incremental (write-time) stats pass."""
    block = sample[None]  # [1, c, *spatial]
    return merge_welford(state, block, (0,) + tuple(range(2, block.ndim)))


def accumulate_store_state(store: ArrayStore, samples=None) -> tuple:
    """(welford_state, n_samples) streamed chunk-wise over complete samples
    (all of them, or the explicit ``samples`` index list)."""
    state = None
    n_samples = 0
    rows = range(store.chunk_grid()[0]) if samples is None else samples
    for i in rows:
        if not store.sample_complete(i):
            continue
        n_samples += 1
        for idx in store.sample_chunk_indices(i):
            chunk = store.read_chunk(idx)
            # layout [1, c, *spatial]: reduce everything but the channel dim
            axis = (0,) + tuple(range(2, chunk.ndim))
            state = merge_welford(state, chunk, axis)
    return state, n_samples


def stats_from_state(state, n_samples: int) -> dict:
    count, mean, m2, amax = state
    std = np.sqrt(np.maximum(m2 / max(count - 1, 1), 0.0))
    return {
        "mean": [float(v) for v in np.atleast_1d(mean)],
        "std": [float(v) for v in np.atleast_1d(std)],
        "absmax": [float(v) for v in np.atleast_1d(amax)],
        "count": int(count),
        "n_samples": n_samples,
    }


def compute_store_stats(store: ArrayStore) -> dict:
    """Chunk-wise Welford over all complete samples -> per-channel stats.

    Reads each chunk exactly once and never materializes more than one chunk
    — the pass streams over blob storage just like training itself.
    """
    state, n_samples = accumulate_store_state(store)
    if state is None:
        raise RuntimeError(f"no complete samples in {store.root}")
    return stats_from_state(state, n_samples)


# -- task arg derivation (deterministic in sample index -> idempotent) -------

def two_phase_args(i: int, args) -> Tuple:
    return (args.seed + i, args.wells, tuple(args.grid), args.nt)


def navier_stokes_args(i: int, args) -> Tuple:
    rng = np.random.default_rng(np.random.SeedSequence([args.seed, i]))
    center = tuple(float(c) for c in rng.uniform(0.25, 0.75, size=3))
    return (center, args.grid[0], args.nt)


def geomodel_channel(grid, nt: int, seed: int = 0) -> np.ndarray:
    """The shared log-permeability geomodel as a [1, nx, ny, nz, nt] input
    channel — the SAME realization every two_phase sample was simulated on
    (``simulate_task`` fixes the geomodel seed), repeated along t. Serving
    reuses this exact construction for its UQ-ensemble scenarios, which is
    what makes the content-hash geomodel cache hit across requests."""
    from repro.data.pde.two_phase import TwoPhaseConfig, make_geomodel

    k, _ = make_geomodel(TwoPhaseConfig(grid=tuple(grid)), seed=seed)
    logk = np.log(np.asarray(k, np.float32))
    return np.repeat(logk[None, :, :, :, None], nt, axis=-1).astype(np.float32)


def to_training_pair(
    pde: str, result, nt: int, geomodel: bool = False
) -> Tuple[np.ndarray, np.ndarray]:
    """(x, y) in the FNO layout [c, nx, ny, nz, nt] (paper: the binary input
    map is repeated along t; the target is the full solution history).
    ``geomodel`` (two_phase) prepends the log-permeability field the sample
    was simulated on as a STATIC input channel."""
    mask, field = result
    x = np.repeat(mask[None, :, :, :, None], nt, axis=-1).astype(np.float32)
    if geomodel:
        x = np.concatenate([geomodel_channel(mask.shape, nt), x], axis=0)
    return x, field[None].astype(np.float32)


def open_or_create(root: str, shape, chunks, resume: bool) -> ArrayStore:
    if resume and os.path.exists(os.path.join(root, "meta.json")):
        store = ArrayStore.open(root)
        if store.shape[1:] != tuple(shape[1:]) or store.chunks != tuple(chunks):
            raise SystemExit(
                f"--resume: existing store {root} has shape {store.shape} "
                f"chunks {store.chunks}, requested {tuple(shape)} / {tuple(chunks)}"
            )
        if store.shape[0] < shape[0]:
            # growing the dataset is just more independent chunk rows
            store.shape = tuple(shape)
            store.update_meta()
        return store
    if os.path.isdir(root):
        # ArrayStore.create would rewrite meta.json but leave old chunk
        # files behind, which then count as complete samples with STALE
        # data under the new meta — refuse rather than serve wrong samples.
        stale = [f for f in os.listdir(root) if f.startswith("c")]
        if stale:
            raise SystemExit(
                f"store {root} already holds {len(stale)} chunk file(s); "
                f"pass --resume to reuse them or delete the directory first"
            )
    return ArrayStore.create(root, shape, "f4", chunks)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--pde", choices=("two_phase", "navier_stokes"), default="two_phase")
    ap.add_argument("--n", type=int, default=8, help="number of training samples")
    ap.add_argument("--grid", type=int, nargs=3, default=(16, 8, 8),
                    help="(nx, ny, nz); navier_stokes uses nx for all dims")
    ap.add_argument("--nt", type=int, default=4)
    ap.add_argument("--wells", type=int, default=2, help="two_phase: injectors/sample")
    ap.add_argument("--geomodel", action="store_true",
                    help="two_phase: prepend the shared log-permeability "
                    "geomodel as a static input channel (what the serving "
                    "geomodel cache keys on)")
    ap.add_argument("--out", required=True, help="dataset root; writes <out>/x, <out>/y")
    ap.add_argument("--chunks-xy", type=int, nargs=2, default=(2, 2), metavar=("CX", "CY"),
                    help="chunk counts along x/y (shard-aligned partial reads)")
    ap.add_argument("--backend", choices=("process", "thread"), default="process")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--vm-type", default="E8s_v3")
    ap.add_argument("--spot", action="store_true")
    ap.add_argument("--speculative", action="store_true",
                    help="re-execute stragglers (first finisher wins)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="skip samples whose chunks are already published")
    ap.add_argument("--no-stats", action="store_true")
    ap.add_argument("--normalizer", choices=("meanstd", "absmax"),
                    default="meanstd",
                    help="normalization kind persisted in meta.json and "
                    "honored by the loader and the serving runner "
                    "(absmax = the paper's normalize-targets-by-max)")
    ap.add_argument("--stats-every", type=int, default=4,
                    help="persist incremental Welford stats to meta.json "
                    "every K completed samples (online training reads them "
                    "before the dataset is finished)")
    return ap


def main(argv=None):
    return run_datagen(build_parser().parse_args(argv))


def run_datagen(args) -> int:
    """Library-callable datagen body (``main`` minus argument parsing) —
    the entry point train.py's ``--online`` mode runs in the background."""
    if args.pde == "two_phase":
        from repro.data.pde.two_phase import simulate_task
        nx, ny, nz = args.grid
        task_args = two_phase_args
    else:
        from repro.data.pde.navier_stokes import simulate_task
        nx = ny = nz = args.grid[0]
        task_args = navier_stokes_args

    geomodel = bool(getattr(args, "geomodel", False))
    if geomodel and args.pde != "two_phase":
        raise SystemExit("--geomodel is a two_phase feature (permeability channel)")
    n_ch = 2 if geomodel else 1  # x only; the target is always 1 channel
    cx, cy = args.chunks_xy
    if nx % cx or ny % cy:
        raise SystemExit(f"grid ({nx},{ny}) not divisible by --chunks-xy ({cx},{cy})")
    chunks = (1, 1, nx // cx, ny // cy, nz, args.nt)
    x_shape = (args.n, n_ch, nx, ny, nz, args.nt)
    y_shape = (args.n, 1, nx, ny, nz, args.nt)
    xs = open_or_create(os.path.join(args.out, "x"), x_shape, chunks, args.resume)
    ys = open_or_create(os.path.join(args.out, "y"), y_shape, chunks, args.resume)

    # run-identity guard: task args are a pure function of (sample index,
    # pde, seed, ...), so --resume may only continue a run with the SAME
    # signature — otherwise kept samples would silently mix distributions
    gen_sig = {
        "pde": args.pde, "seed": args.seed, "nt": args.nt,
        "wells": args.wells if args.pde == "two_phase" else None,
        "geomodel": geomodel,
    }
    for store in (xs, ys):
        prev = store.meta.get("gen")
        if prev is not None:
            prev = {"geomodel": False, **prev}  # stores predating the flag
        if prev is not None and prev != gen_sig:
            raise SystemExit(
                f"store {store.root} was generated with {prev}, this run "
                f"asks for {gen_sig}; refusing to mix samples — use a "
                f"fresh --out (or matching --pde/--seed/--nt/--wells)"
            )
        if prev is None:
            store.update_meta(gen=gen_sig)
        # the kind is presentation (how stats are APPLIED), not data: safe
        # to (re)persist on every run, including --resume
        if store.meta.get("normalizer") != args.normalizer:
            store.update_meta(normalizer=args.normalizer)

    todo: List[int] = [
        i for i in range(args.n)
        if not (args.resume and xs.sample_complete(i) and ys.sample_complete(i))
    ]
    print(f"datagen: {args.n} samples requested, {args.n - len(todo)} already "
          f"complete, simulating {len(todo)} ({args.pde})")

    # incremental Welford: seed from samples already in the store (resume),
    # then merge each new sample as it is written, persisting to meta.json
    # every --stats-every samples so an ONLINE trainer sees normalization
    # stats long before the dataset is finished.
    track_stats = not args.no_stats
    stats_every = max(1, getattr(args, "stats_every", 4))
    state_x = state_y = None
    n_stat = 0
    if track_stats and todo and len(todo) < args.n:
        done_already = sorted(set(range(args.n)) - set(todo))
        state_x, n_stat = accumulate_store_state(xs, done_already)
        state_y, _ = accumulate_store_state(ys, done_already)

    def _persist_stats():
        if state_x is not None:
            xs.update_meta(stats=stats_from_state(state_x, n_stat))
        if state_y is not None:
            ys.update_meta(stats=stats_from_state(state_y, n_stat))

    if todo:
        backend = (
            LocalProcessBackend(args.workers) if args.backend == "process"
            else ThreadBackend(args.workers)
        )
        pool = BatchPool(
            backend,
            store_root=os.path.join(args.out, "blobs"),
            vm_type=args.vm_type,
            n_vms=args.workers,
            spot=args.spot,
        )
        try:
            if args.speculative:
                # straggler re-execution needs the full future set in flight
                results = pool.map(
                    simulate_task,
                    [task_args(i, args) for i in todo],
                    speculative=True,
                )
                pairs = zip(todo, results)
            else:
                # write each sample as its task resolves: a preempted run
                # keeps everything finished so far (--resume picks up the
                # rest), and only one result is in memory at a time
                futures = [
                    pool.submit(simulate_task, task_args(i, args)) for i in todo
                ]
                pairs = ((i, f.result()) for i, f in zip(todo, futures))
            for i, result in pairs:
                x, y = to_training_pair(args.pde, result, args.nt, geomodel)
                xs.write_sample(i, x)
                ys.write_sample(i, y)
                if track_stats:
                    state_x = merge_sample_welford(state_x, x)
                    state_y = merge_sample_welford(state_y, y)
                    n_stat += 1
                    if n_stat % stats_every == 0:
                        _persist_stats()
            rep = pool.cost_report()
            print(
                f"datagen: {rep['tasks']} tasks, mean {rep['mean_task_s']:.2f}s/task, "
                f"${rep['usd']:.4f} on {rep['vm_type']}"
                f"{' (spot)' if rep['spot'] else ''}, "
                f"speculated {rep['speculated']}"
            )
        finally:
            pool.shutdown()

    done = min(xs.n_complete(), ys.n_complete())
    print(f"datagen: {done}/{args.n} samples complete in {args.out}")
    if track_stats and done:
        if state_x is not None:
            _persist_stats()
        for name, store in (("x", xs), ("y", ys)):
            # a rerun with nothing to simulate keeps the persisted stats
            # bit-identical; otherwise fall back to the full streaming pass
            stats = store.meta.get("stats")
            if stats is None:
                stats = compute_store_stats(store)
                store.update_meta(stats=stats)
            print(
                f"stats[{name}]: mean {['%.4g' % m for m in stats['mean']]} "
                f"std {['%.4g' % s for s in stats['std']]} "
                f"({stats['n_samples']} samples)"
            )
    return done


if __name__ == "__main__":
    main()
