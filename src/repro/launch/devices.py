"""Pre-jax device-count plumbing shared by the launch CLIs.

``--devices N`` on CPU means "simulate N host devices", which XLA only
honors if ``--xla_force_host_platform_device_count`` is set BEFORE the
first jax import. Each CLI therefore sniffs argv and sets the flag at the
very top of its module, before importing anything that imports jax — which
is why this module must never import jax (directly or transitively).
"""
from __future__ import annotations

import os


def sniff_devices(argv):
    """Pre-argparse --devices value, handling BOTH ``--devices N`` and
    ``--devices=N`` (the latter used to be silently ignored, running on one
    device). Must be evaluated before any jax import."""
    for i, tok in enumerate(argv):
        if tok == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if tok.startswith("--devices="):
            return tok.split("=", 1)[1]
    return None


# Latency-hiding flags for communication/compute overlap: let XLA's
# scheduler fly one channel-chunk's all-to-all (see FNOConfig.comm_chunks)
# while the next chunk's local FFTs compute. NOTE: the classic
# --xla_gpu_enable_async_collectives flag is deliberately ABSENT — recent
# jaxlibs removed it (async collectives are on by default) and XLA
# hard-crashes on unknown XLA_FLAGS entries.
OVERLAP_XLA_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def overlap_flags() -> str:
    """The overlap flag string, or "" when opted out via
    REPRO_NO_OVERLAP_FLAGS=1 (e.g. to A/B the scheduler's effect)."""
    if os.environ.get("REPRO_NO_OVERLAP_FLAGS"):
        return ""
    return " ".join(OVERLAP_XLA_FLAGS)


def apply_device_flag(argv) -> None:
    """Set the XLA host-device-count flag if argv carries --devices, plus
    the latency-hiding scheduler flags (harmless on CPU; on GPU they enable
    the collective overlap the chunked repartition path is shaped for)."""
    n = sniff_devices(argv)
    if n is not None:
        flags = f"--xla_force_host_platform_device_count={n} {overlap_flags()}"
        os.environ["XLA_FLAGS"] = flags.strip()
