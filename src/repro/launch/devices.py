"""Pre-jax device-count plumbing shared by the launch CLIs.

``--devices N`` on CPU means "simulate N host devices", which XLA only
honors if ``--xla_force_host_platform_device_count`` is set BEFORE the
first jax import. Each CLI therefore sniffs argv and sets the flag at the
very top of its module, before importing anything that imports jax — which
is why this module must never import jax (directly or transitively).
"""
from __future__ import annotations

import os


def sniff_devices(argv):
    """Pre-argparse --devices value, handling BOTH ``--devices N`` and
    ``--devices=N`` (the latter used to be silently ignored, running on one
    device). Must be evaluated before any jax import."""
    for i, tok in enumerate(argv):
        if tok == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if tok.startswith("--devices="):
            return tok.split("=", 1)[1]
    return None


def apply_device_flag(argv) -> None:
    """Set the XLA host-device-count flag if argv carries --devices."""
    n = sniff_devices(argv)
    if n is not None:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
