"""Post-optimization HLO analysis: collective bytes for the roofline.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse ``compiled.as_text()``: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction contributes
wire bytes per device according to standard ring-algorithm cost models.

Collectives inside while loops (the scan-over-layers body, grad-accum loop,
kv-chunk scans) execute trip-count times; we recover trip counts from each
while's condition computation (XLA canonicalizes induction compares against
a constant), falling back to 1 when unparseable.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
# e.g. "%all-reduce.5 = f32[8,16]{1,0} all-reduce(" or tuple results
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[\w\[\]{},\s]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_DEF_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},.]+))")
_HEADER_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([\w\[\],]+)")
# Operands may carry inline types ("dot(f32[64,64]{1,0} %lhs, ...)"), which
# newer HLO emitters always print; the type prefix is optional here.
_DOT_RE = re.compile(
    r"=\s*(?P<result>[\w\[\]{},.]+)\s+dot\("
    r"(?:[\w\[\]{},.]+\s+)?%?(?P<lhs>[\w.\-]+),\s*"
    r"(?:[\w\[\]{},.]+\s+)?%?(?P<rhs>[\w.\-]+)\)"
    r".*?lhs_contracting_dims=\{(?P<lcd>[\d,]*)\}"
)
_FFT_RE = re.compile(r"=\s*(?P<result>[\w\[\]{},.]+)\s+fft\(.*?fft_length=\{(?P<len>[\d,]+)\}")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONST_RE = re.compile(r"=\s*[su]32\[\]\s*constant\((\d+)\)")
# XLA annotates canonicalized loops with the exact trip count; prefer it
# over reverse-engineering the condition's compare constant.
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    """Per-device bytes on the wire (ring-algorithm model)."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-gather":
        return result_bytes * frac          # receives (g-1)/g of the output
    if kind == "all-reduce":
        return 2.0 * result_bytes * frac    # reduce-scatter + all-gather
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)       # result is the scattered shard
    if kind == "all-to-all":
        return result_bytes * frac          # sends (g-1)/g of its tile
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    bytes_by_site: Dict[str, float] = dataclasses.field(default_factory=dict)
    # wire bytes issued through async ``<kind>-start`` ops: these fly on the
    # collective stream while compute continues, so the latency-hiding
    # scheduler can overlap them (vs. sync collectives that serialize)
    overlapped_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def overlap_fraction(self) -> float:
        t = self.total_bytes
        return self.overlapped_bytes / t if t else 0.0

    def top_sites(self, n: int = 10):
        return sorted(self.bytes_by_site.items(), key=lambda kv: -kv[1])[:n]

    def to_dict(self) -> dict:
        return {
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
            "total_bytes": self.total_bytes,
            "overlapped_bytes": self.overlapped_bytes,
            "overlap_fraction": self.overlap_fraction,
            "top_sites": self.top_sites(8),
        }


def _site_of(line: str) -> str:
    m = _OPNAME_RE.search(line)
    if not m:
        return "?"
    # keep a compact, meaningful tail of the op path
    parts = m.group(1).split("/")
    return "/".join(parts[-3:])[:120]


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and stripped.endswith("{"):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        if stripped == "}":
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = []
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution-count multiplier per computation: while-loop bodies run
    trip-count times; fusion/reduce bodies run as often as their caller."""
    mult = defaultdict(lambda: 1.0)
    pending = []  # (parent, child, factor)
    for cname, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                known = _KNOWN_TRIP_RE.search(line)
                if known:
                    trips = int(known.group(1))
                else:
                    trips = _trip_count(comps.get(cond, []))
                pending.append((cname, cond, 1))
                pending.append((cname, body, trips))
                continue
            c = _CALLS_RE.search(line)
            if c:
                pending.append((cname, c.group(1), 1))
    for _ in range(16):
        changed = False
        for parent, child, factor in pending:
            new = mult[parent] * factor
            if mult[child] != new:
                mult[child] = new
                changed = True
        if not changed:
            break
    return mult


def _comp_shapes(comps: Dict[str, List[str]], headers: Dict[str, str]) -> Dict[str, Dict[str, str]]:
    """Per-computation map: instruction/param name -> result type string."""
    shapes: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        local = {}
        header = headers.get(cname, "")
        if "(" in header:
            arglist = header[header.index("(") + 1 :]
            depth = 1
            end = 0
            for i, ch in enumerate(arglist):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            for pm in _HEADER_PARAM_RE.finditer(arglist[:end]):
                local[pm.group(1)] = pm.group(2)
        for line in lines:
            line = line.lstrip("ROOT ").strip()
            dm = _DEF_RE.match(line)
            if dm:
                local[dm.group(1)] = dm.group(2)
        shapes[cname] = local
    return shapes


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str or "")
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def collect_compute(hlo: str) -> Dict[str, float]:
    """Loop-aware FLOPs and rough HBM-traffic estimate.

    XLA's ``cost_analysis()`` counts while bodies ONCE; here every dot/fft
    inside a loop body is weighted by the loop trip count (recovered from
    the while condition), and fusion bodies inherit their caller's count.
    flops: dot = 2*prod(result)*K; fft = 5*N*log2(L).
    bytes_est: every materialized result written once + read once (x2),
    weighted by execution count — an upper-bound traffic model.
    """
    comps, headers = _split_computations_with_headers(hlo)
    mult = _multipliers(comps)
    shapes = _comp_shapes(comps, headers)
    flops = 0.0
    bytes_est = 0.0
    import math

    for cname, lines in comps.items():
        m = mult[cname]
        local = shapes[cname]
        is_fused = cname not in headers or "fused" in cname or "wrapped" in cname
        for line in lines:
            dm = _DOT_RE.search(line)
            if dm:
                res = _shape_dims(dm.group("result"))
                lhs = _shape_dims(local.get(dm.group("lhs"), ""))
                lcd = [int(i) for i in dm.group("lcd").split(",") if i]
                k = 1
                for i in lcd:
                    if i < len(lhs):
                        k *= lhs[i]
                n = 1
                for d in res:
                    n *= d
                flops += m * 2.0 * n * k
                continue
            fm = _FFT_RE.search(line)
            if fm:
                res = _shape_dims(fm.group("result"))
                n = 1
                for d in res:
                    n *= d
                ln = 1
                for d in fm.group("len").split(","):
                    ln *= int(d)
                flops += m * 5.0 * n * max(math.log2(max(ln, 2)), 1.0)
                continue
        if not is_fused:
            # traffic estimate over materialized (non-fusion-internal) results
            for line in lines:
                dm = _DEF_RE.match(line.lstrip("ROOT ").strip())
                if dm:
                    bytes_est += m * 2.0 * _shape_bytes(dm.group(2))
    return {"flops": flops, "bytes_est": bytes_est}


def _split_computations_with_headers(hlo: str):
    comps: Dict[str, List[str]] = {}
    headers: Dict[str, str] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and stripped.endswith("{"):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                headers[current] = stripped
                continue
        if stripped == "}":
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps, headers


def _tuple_elements(result: str) -> List[str]:
    """Top-level elements of a tuple type string "(f32[8], f32[8,2])"."""
    inner = result.strip()
    if not (inner.startswith("(") and inner.endswith(")")):
        return [inner]
    inner = inner[1:-1]
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(inner):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(inner[start:i])
            start = i + 1
    parts.append(inner[start:])
    return [p for p in (p.strip() for p in parts) if p]


def _async_result_bytes(kind: str, result: str) -> int:
    """Payload bytes of an async ``<kind>-start``, whose result is a tuple
    aliasing the operand alongside the eventual output (plus scalar context
    on some backends). The ring model wants only the OUTPUT's bytes: the
    largest element in general, the smallest for reduce-scatter (its output
    is the scattered shard)."""
    sizes = [b for b in (_shape_bytes(e) for e in _tuple_elements(result)) if b]
    if not sizes:
        return 0
    return min(sizes) if kind == "reduce-scatter" else max(sizes)


def collect_collectives(hlo: str, n_devices_default: int = 1) -> CollectiveStats:
    comps = _split_computations(hlo)
    mult = _multipliers(comps)
    bytes_by_kind: Dict[str, float] = defaultdict(float)
    count_by_kind: Dict[str, int] = defaultdict(int)
    bytes_by_site: Dict[str, float] = defaultdict(float)
    overlapped = 0.0
    for cname, lines in comps.items():
        m = mult[cname]
        for line in lines:
            om = _OP_RE.search(line)
            if not om:
                continue
            kind = om.group("kind")
            if om.group("start") is None and f"{kind}-done" in line:
                continue  # bytes were accounted at the -start half
            is_async = om.group("start") is not None
            if is_async:
                rb = _async_result_bytes(kind, om.group("result"))
            else:
                rb = _shape_bytes(om.group("result"))
            g = _group_size(line, n_devices_default)
            wire = m * _wire_bytes(kind, rb, g)
            bytes_by_kind[kind] += wire
            count_by_kind[kind] += int(m)
            bytes_by_site[f"{kind}:{_site_of(line)}"] += wire
            if is_async:
                overlapped += wire
    return CollectiveStats(
        dict(bytes_by_kind), dict(count_by_kind), dict(bytes_by_site), overlapped
    )


def peak_memory_bytes(memory_stats) -> int:
    """Per-device live-memory estimate from CompiledMemoryStats."""
    return int(
        memory_stats.argument_size_in_bytes
        + memory_stats.output_size_in_bytes
        - memory_stats.alias_size_in_bytes
        + memory_stats.temp_size_in_bytes
    )
