"""Batched LM serving driver: the shared slot scheduler on a reduced arch."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import init_lm_params
from repro.serve import Engine, Request, SERVABLE_FAMILIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    if cfg.family not in SERVABLE_FAMILIES:
        # fail here, with the fix, instead of deep inside runner setup
        raise SystemExit(
            f"--arch {args.arch} (family {cfg.family!r}) is not servable by "
            f"the token engine; supported families: "
            f"{', '.join(SERVABLE_FAMILIES)}. Encoder-decoder archs are "
            f"served via the whisper_* entry points (examples/serve_lm.py)."
        )
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_len=args.max_len, max_batch=args.max_batch)

    rng = np.random.default_rng(0)
    for r in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(3, 9)).tolist()
        engine.submit(Request(rid=r, prompt=prompt, max_tokens=args.max_tokens))

    t0 = time.time()
    done = engine.run_until_done()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(
        f"{args.arch}: served {len(done)} requests, {total_tokens} tokens in "
        f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile), "
        f"{engine.steps} scheduler steps (continuous batching over "
        f"{args.max_batch} slots)"
    )
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} -> {r.output}")


if __name__ == "__main__":
    main()
