"""Serve the trained CO2 surrogate: UQ-ensemble inference through the
family-generic scheduler.

The paper's payoff workload: thousands of sequential simulations (well-
placement optimization, uncertainty quantification) become tractable when
the surrogate replaces the numerical simulator. This driver draws N
permeability/well-placement scenarios from the ``two_phase`` generator,
serves them through the shared slot scheduler with model-parallel FNO
inference (``FNORunner.from_checkpoint``), and reports scenarios/s plus
per-request latency.

    PYTHONPATH=src python -m repro.launch.datagen --pde two_phase --n 8 \
        --grid 16 8 8 --nt 4 --out /tmp/co2_ds
    PYTHONPATH=src python src/repro/launch/train.py --mode fno \
        --x-store /tmp/co2_ds/x --y-store /tmp/co2_ds/y --ckpt-dir /tmp/ck
    PYTHONPATH=src python src/repro/launch/serve_pde.py --ckpt-dir /tmp/ck \
        --scenarios 8 --verify --bench-sequential

``--verify`` replays every served scenario through the serial
``fno_forward`` oracle (same normalization chain) and fails loudly on
mismatch; ``--bench-sequential`` also serves the ensemble one-at-a-time
through a single-slot scheduler over the same warm runner, reporting the
continuous-batching speedup; ``--reference`` times the numerical simulator
on one scenario for the paper's surrogate-vs-simulator speedup.
"""
import sys

# must precede any jax import (repro.launch.devices never imports jax)
from repro.launch.devices import apply_device_flag

apply_device_flag(sys.argv)

import argparse
import time

import numpy as np


def build_scenarios(cfg, n: int, wells: int, seed: int, steps: int,
                    n_static: int = 0, dup: int = 1):
    """N well-placement scenarios in the model's input layout.

    ``n_static > 0`` builds the UQ-ensemble workload: the first channels
    are the SHARED log-permeability geomodel (byte-identical across every
    scenario — ``datagen --geomodel``'s construction, so a checkpoint
    trained on such a store serves in-distribution), only the well channel
    varies. ``dup`` submits each scenario that many times (duplicates get
    fresh rids; the scheduler dedups them in flight).
    """
    from repro.data.pde.two_phase import TwoPhaseConfig, random_well_mask
    from repro.launch.datagen import geomodel_channel
    from repro.serve import ScenarioRequest

    nx, ny, nz, nt = cfg.grid
    sim_cfg = TwoPhaseConfig(grid=(nx, ny, nz), nt_frames=nt)
    geo = None
    if n_static:
        one = geomodel_channel((nx, ny, nz), nt)
        geo = np.concatenate([one] * n_static, axis=0)[:n_static]
    requests, rid = [], 0
    n_dyn = cfg.in_channels - n_static
    for i in range(n):
        mask = random_well_mask(sim_cfg, wells, seed + i)
        x = np.repeat(
            mask[None, :, :, :, None], nt, axis=-1
        ).astype(np.float32)
        if n_dyn > 1:
            x = np.concatenate([x] * n_dyn, axis=0)[:n_dyn]
        if geo is not None:
            x = np.concatenate([geo, x], axis=0)
        for _ in range(max(1, dup)):
            requests.append(ScenarioRequest(rid=rid, x=x.copy(), steps=steps))
            rid += 1
    return requests, sim_cfg


def oracle_rollout(runner, x_raw: np.ndarray, steps: int):
    """Per-request reference: serial fno_forward (batch 1) through the same
    normalize -> forward -> de-normalize -> feedback chain.

    Runs on HOST-gathered (replicated) params: jit on the runner's model-
    sharded param tree would re-partition the serial graph through GSPMD,
    which mis-partitions the composed-FFT path on jax 0.4.x — the oracle
    must stay a genuinely single-device reference.
    """
    import dataclasses

    import jax

    from repro.core import fno_forward
    from repro.core.fno import params_without_planes

    cached = getattr(runner, "_oracle_cache", None)
    if cached is None:
        # one host gather + one jit for ALL oracle calls against this
        # runner (a fresh lambda per call would defeat the jit cache and
        # recompile the serial FNO once per scenario). The oracle is the
        # UNFUSED serial forward on complex params: when the runner serves
        # the fused Pallas path (plane-cached params), --verify is a true
        # fused-vs-unfused equivalence gate, not a self-comparison.
        oracle_cfg = dataclasses.replace(runner.cfg, use_pallas=False)
        cached = runner._oracle_cache = (
            params_without_planes(jax.device_get(runner.params)),
            jax.jit(lambda p, x: fno_forward(p, x, oracle_cfg)),
        )
    params, fwd = cached
    n_static = getattr(runner, "n_static", 0)
    outs, x = [], np.asarray(x_raw, np.float32)
    for _ in range(steps):
        xe = runner.x_normalizer.encode(x[None])
        y = np.asarray(fwd(params, xe))
        y_raw = runner.y_normalizer.decode(y)[0]
        outs.append(y_raw)
        fb = runner.feedback(y_raw)
        # with static geomodel channels, feedback evolves only the dynamic
        # channels — the geomodel persists (mirrors FNORunner.step)
        x = np.concatenate([x[:n_static], fb], axis=0) if n_static else fb
    return outs


def serve(runner, requests, max_slots: int, max_steps: int):
    """(finished, seconds, scheduler) for one serving pass over
    ``requests``. Callers must check ``sched.failed`` / the served count
    (``check_served``) — a scenario that fails admission is REPORTED, not
    an excuse to crash downstream."""
    from repro.serve import Scheduler

    sched = Scheduler(runner, max_slots)
    for r in requests:
        sched.submit(r)
    t0 = time.perf_counter()
    done = sched.run_until_done(max_steps=max_steps)
    dt = time.perf_counter() - t0
    return done, dt, sched


def check_served(done, requests, failed):
    """Exit nonzero with the per-request errors when the ensemble did not
    fully serve. An all-failed ensemble (e.g. a wrong --static-channels /
    --rollout-steps makes every admit raise) must report each admit error
    and exit — not crash on an empty latency list."""
    for r in failed:
        print(f"scenario rid={r.rid} FAILED: {r.error}", file=sys.stderr)
    if failed:
        raise SystemExit(
            f"{len(failed)}/{len(requests)} scenario(s) failed "
            f"(errors above); {len(done)} served"
        )
    if len(done) != len(requests):
        raise SystemExit(
            f"served {len(done)}/{len(requests)} scenarios; "
            f"raise --max-steps"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True,
                    help="train.py --mode fno checkpoint directory")
    ap.add_argument("--scenarios", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4, help="scheduler slots")
    ap.add_argument("--rollout-steps", type=int, default=1,
                    help="autoregressive surrogate applications per scenario")
    ap.add_argument("--wells", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=None,
                    help="simulated host devices (CPU); default: all visible")
    ap.add_argument("--model-shards", type=int, nargs="+", default=None,
                    help="serving-mesh model parallelism; default: the "
                    "layout recorded in the checkpoint's fno_config.json")
    ap.add_argument("--max-steps", type=int, default=10000)
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas behind the gateway; each is an "
                    "independent FNORunner + scheduler restored from the "
                    "same checkpoint (1 = the pre-gateway single-scheduler "
                    "path, bit-identical to earlier releases)")
    ap.add_argument("--policy", default="affinity",
                    choices=("least-pending", "round-robin", "affinity"),
                    help="gateway routing policy (--replicas > 1): "
                    "backlog-aware least-pending, cyclic round-robin, or "
                    "geomodel cache-affinity with least-pending fallback")
    ap.add_argument("--ensemble", action="store_true",
                    help="UQ-ensemble mode: every scenario shares the same "
                    "geomodel (static channels), only well locations vary; "
                    "serves through the content-hash geomodel cache and "
                    "reports its hit-rate")
    ap.add_argument("--static-channels", type=int, default=1,
                    help="ensemble mode: leading input channels that are "
                    "the static geomodel (a --geomodel datagen store "
                    "trains a 2-channel model -> 1 static channel)")
    ap.add_argument("--cache-bytes", type=int, default=256 << 20,
                    help="geomodel-cache byte budget (LRU beyond it)")
    ap.add_argument("--cache-level", default="deep",
                    choices=("prelift", "deep"),
                    help="ensemble cache depth: 'prelift' stops at the "
                    "encoder lift; 'deep' (default) also caches the first "
                    "block's static kept-mode spectra + weight-mixed "
                    "contribution and serves the deep-split forward")
    ap.add_argument("--cache-store", default=None,
                    help="fleet-shared cache store replicas consult on "
                    "local miss: 'dict' for an in-process shared dict, or "
                    "a directory path for a file-backed (.npz) store that "
                    "persists across runs")
    ap.add_argument("--dup", type=int, default=1,
                    help="submit each scenario this many times (identical "
                    "in-flight requests dedup onto one slot)")
    ap.add_argument("--verify", action="store_true",
                    help="check every served output against the serial "
                    "fno_forward oracle (exit nonzero on mismatch)")
    ap.add_argument("--bench-sequential", action="store_true",
                    help="also serve one-at-a-time and report the "
                    "continuous-batching speedup")
    ap.add_argument("--reference", action="store_true",
                    help="time the numerical simulator on one scenario for "
                    "the surrogate-vs-simulator speedup")
    ap.add_argument("--use-pallas", action="store_true", default=None,
                    help="serve through the fused Pallas spectral path "
                    "(plane-cached weights); default: whatever the "
                    "checkpoint's fno_config.json recorded")
    ap.add_argument("--comm-chunks", type=int, default=None,
                    help="channel-chunked all-to-all overlap for the dist "
                    "forward; default: the checkpoint's recorded value")
    args = ap.parse_args()

    from repro.serve import FNORunner, Gateway, open_cache_store

    if args.replicas < 1:
        raise SystemExit(f"--replicas must be >= 1, got {args.replicas}")
    n_static = args.static_channels if args.ensemble else 0
    # one store shared by every replica — that is the point of the tier
    store = (
        open_cache_store(args.cache_store)
        if args.cache_store and n_static else None
    )

    def load_runner():
        return FNORunner.from_checkpoint(
            args.ckpt_dir,
            model_shards=args.model_shards,
            max_slots=args.max_batch,
            n_static=n_static,
            cache_bytes=args.cache_bytes,
            cache_level=args.cache_level,
            cache_store=store,
            use_pallas=args.use_pallas,
            comm_chunks=args.comm_chunks,
        )

    try:
        runners = [load_runner() for _ in range(args.replicas)]
    except ValueError as e:  # library error -> CLI-flag wording
        raise SystemExit(f"--devices/--model-shards/--static-channels: {e}") from None
    runner = runners[0]
    cfg = runner.cfg
    print(
        f"serving {cfg.grid} FNO (width {cfg.width}, {cfg.n_blocks} blocks) "
        f"from step {runner.restored_step} on mesh "
        f"{dict(runner.mesh.shape)} (buckets {runner.buckets}"
        + (f", {args.replicas} replicas policy={args.policy})"
           if args.replicas > 1 else ")")
    )
    compile_s = sum(r.warmup() for r in runners)

    requests, sim_cfg = build_scenarios(
        cfg, args.scenarios, args.wells, args.seed, args.rollout_steps,
        n_static=n_static, dup=args.dup,
    )
    if args.replicas == 1:
        # the pre-gateway path, untouched: one scheduler, bit-identical
        done, dt, sched = serve(runner, requests, args.max_batch, args.max_steps)
        check_served(done, requests, sched.failed)
        engine_steps = sched.steps
        dedup_attached = sched.dedup_attached
        fleet_stats = None
    else:
        gateway = Gateway(runners, policy=args.policy)
        for r in requests:
            gateway.submit(r)
        t0 = time.perf_counter()
        done = gateway.run_until_done(max_steps=args.max_steps)
        dt = time.perf_counter() - t0
        check_served(done, requests, gateway.failed)
        stats = gateway.stats()
        fleet_stats = stats["fleet"]
        engine_steps = fleet_stats["ticks"]
        dedup_attached = fleet_stats["dedup_attached"]
        for rs in stats["replicas"]:
            print(
                f"  replica {rs['name']}: routed {rs['routed']}, served "
                f"{rs['finished']}, backlog {rs['pending']}, healthy "
                f"{rs['healthy']}"
                + (f", cache hit-rate {rs['cache']['hit_rate']:.3f} "
                   f"({rs['cache']['bytes'] / 1e6:.2f} MB)"
                   if rs["cache"] else "")
            )
    lat = sorted(r.finished_s - r.submitted_s for r in done)
    n = len(done)
    forwards = sum(r.batched_steps for r in runners)
    if n:
        print(
            f"served {n} scenarios x {args.rollout_steps} rollout step(s) in "
            f"{dt:.3f}s ({n / dt:.2f} scen/s, compile {compile_s:.2f}s excluded) "
            f"over {engine_steps} engine steps / {forwards} forwards; "
            f"latency p50 {lat[n // 2] * 1e3:.1f}ms p95 "
            f"{lat[min(n - 1, int(n * 0.95))] * 1e3:.1f}ms"
        )
    if args.replicas == 1 and runner.cache is not None:
        s = runner.cache.stats
        lv = s["level_bytes"]
        print(
            f"geomodel cache: hit-rate {s['hit_rate']:.3f} "
            f"({s['hits']} hits / {s['misses']} misses, {s['entries']} "
            f"entries, {s['bytes'] / 1e6:.2f} MB, {s['evictions']} evicted, "
            f"{s['deep_evictions']} deep-evicted); level MB "
            + "/".join(f"{lv[k] / 1e6:.2f}" for k in lv)
            + f" ({'/'.join(lv)}); dedup attached {dedup_attached} follower(s)"
        )
    elif fleet_stats is not None and (
        fleet_stats["cache_hits"] + fleet_stats["cache_misses"]
    ):
        print(
            f"fleet geomodel cache: hit-rate "
            f"{fleet_stats['cache_hit_rate']:.3f} "
            f"({fleet_stats['cache_hits']} hits / "
            f"{fleet_stats['cache_misses']} misses across "
            f"{fleet_stats['n_replicas']} replicas, "
            f"{fleet_stats['cache_bytes'] / 1e6:.2f} MB); dedup attached "
            f"{dedup_attached} follower(s)"
        )
    if store is not None:
        ss = store.stats
        print(
            f"cache store: {ss['hits']} hits / {ss['misses']} misses "
            f"({ss['hit_rate']:.3f}), {ss['puts']} puts, {ss['entries']} "
            f"entries, {ss['bytes'] / 1e6:.2f} MB"
        )

    if args.bench_sequential:
        seq_requests, _ = build_scenarios(
            cfg, args.scenarios, args.wells, args.seed, args.rollout_steps,
            n_static=n_static, dup=args.dup,
        )
        seq_done, seq_dt, seq_sched = serve(runner, seq_requests, 1, args.max_steps)
        check_served(seq_done, seq_requests, seq_sched.failed)
        speedup = seq_dt / dt
        print(
            f"sequential: {len(seq_done)} scenarios in {seq_dt:.3f}s "
            f"({len(seq_done) / seq_dt:.2f} scen/s); continuous batching "
            f"speedup {speedup:.2f}x"
        )

    if args.verify:
        worst = 0.0
        for r in done:
            expected = oracle_rollout(runner, r.x, args.rollout_steps)
            for got, exp in zip(r.outputs, expected):
                worst = max(worst, float(np.abs(got - exp).max()))
                np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-5)
        print(f"verify OK: {n} scenarios match the serial oracle "
              f"(max abs diff {worst:.2e})")

    if args.reference:
        from repro.data.pde.two_phase import simulate_task

        t0 = time.perf_counter()
        simulate_task(args.seed, args.wells, sim_cfg.grid, cfg.grid[3])
        sim_s = time.perf_counter() - t0
        per_scen = dt / n
        print(
            f"reference simulator: {sim_s:.2f}s/scenario vs surrogate "
            f"{per_scen * 1e3:.1f}ms/scenario -> {sim_s / per_scen:.0f}x "
            f"(paper reports ~1e5x at Sleipner scale on real accelerators)"
        )


if __name__ == "__main__":
    main()
