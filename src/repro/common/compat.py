"""Version-compatibility shims for the jax API surface this repo uses.

The codebase targets the modern jax API (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``) but must also run on
jax 0.4.x, where shard_map lives in ``jax.experimental.shard_map`` (with
``check_rep``) and meshes have no axis types. Every call site goes through
these two helpers instead of touching ``jax.*`` directly, so the drift is
handled in exactly one place.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version.

    The distributed FFT paths intentionally return shards whose replication
    cannot be inferred statically, so the repo always disables the check
    (``check_vma=False`` on modern jax, ``check_rep=False`` on 0.4.x).
    """
    kwargs = {}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = False
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = False
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def axis_size(axis_name: str):
    """Size of a named mesh axis, from inside shard_map, on any jax version.

    ``jax.lax.axis_size`` only exists on modern jax; 0.4.x reads the size
    off the axis environment frame instead.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    # psum of 1 over the axis == axis size; XLA constant-folds it, so no
    # collective is actually emitted.
    return jax.lax.psum(1, axis_name)


def make_global_array(shape, sharding, fetch):
    """Assemble a globally-sharded ``jax.Array`` from per-shard host reads.

    ``fetch(index)`` receives a normalized tuple of ``slice`` objects (one
    per dim, concrete start/stop) and must return the numpy block for that
    shard. It is called once per UNIQUE shard index — replicated shards
    (e.g. across a data-parallel axis that doesn't split the dim) reuse the
    first fetch — which is what keeps per-process reads proportional to the
    process's share of the data, not the global array.

    ``jax.make_array_from_callback`` exists on every supported jax (0.4.x
    and modern); the per-version drift is only in how indices are
    normalized, which is handled here so call sites stay version-free.
    """
    shape = tuple(shape)
    memo = {}

    def cb(index):
        norm = tuple(sl.indices(dim) for sl, dim in zip(index, shape))
        if norm not in memo:
            memo[norm] = fetch(
                tuple(slice(a, b, c) for a, b, c in norm)
            )
        return memo[norm]

    return jax.make_array_from_callback(shape, sharding, cb)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    jax >= 0.5 wants axis types spelled out (silences the sharding-in-types
    migration warning); jax 0.4.x has neither ``axis_types`` nor
    ``jax.sharding.AxisType``.
    """
    kwargs = {}
    if "axis_types" in inspect.signature(jax.make_mesh).parameters and hasattr(
        jax.sharding, "AxisType"
    ):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
