"""Small pytree / numerics utilities shared across subsystems."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStructs too)."""
    leaves = jax.tree.leaves(tree)
    total = 0
    for leaf in leaves:
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            continue
        size = 1
        for d in shape:
            size *= int(d)
        total += size * jnp.dtype(dtype).itemsize
    return total


def tree_params(tree) -> int:
    """Total parameter count of all array leaves."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = getattr(leaf, "shape", ())
        size = 1
        for d in shape:
            size *= int(d)
        total += size
    return total


def tree_cast(tree, dtype):
    """Cast all inexact leaves to dtype (leave ints/bools alone)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating) or jnp.issubdtype(
            x.dtype, jnp.complexfloating
        ):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def tree_all_finite(tree) -> jax.Array:
    """Scalar bool: every inexact leaf is finite."""
    leaves = [
        jnp.all(jnp.isfinite(x))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact)
    ]
    if not leaves:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(leaves))


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)
