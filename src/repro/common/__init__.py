from repro.common import constants  # noqa: F401
