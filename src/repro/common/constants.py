"""Hardware constants for the roofline model (TPU v5e target).

The container is CPU-only; these numbers parameterize the analytic roofline
derived from AOT-compiled HLO (see launch/roofline.py). Values provided by
the assignment brief.
"""

# Per-chip peak bf16 matmul throughput.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s

# Per-chip HBM bandwidth.
HBM_BANDWIDTH = 819e9  # B/s

# Per-link ICI bandwidth (one direction). v5e has a 2D torus; each chip has
# 4 links (x+/x-/y+/y-). We report the collective term against a single link
# per the brief ("~50 GB/s/link ICI").
ICI_BANDWIDTH_PER_LINK = 50e9  # B/s
ICI_LINKS_PER_CHIP = 4

# HBM capacity per v5e chip (for fit checks in EXPERIMENTS.md commentary).
HBM_BYTES_PER_CHIP = 16 * 1024**3

# Production mesh shape (per pod).
POD_MESH_SHAPE = (16, 16)
POD_MESH_AXES = ("data", "model")
MULTIPOD_MESH_SHAPE = (2, 16, 16)
MULTIPOD_MESH_AXES = ("pod", "data", "model")

# Mesh axis names used throughout the framework.
AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"
