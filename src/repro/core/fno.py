"""Fourier Neural Operator — serial oracle and model-parallel (paper Alg. 1/2).

Functional, pytree-parameterized. The *same parameter pytree* drives:
  * ``fno_forward``        — single-device oracle (rfftn over all 4 dims),
  * ``fno_forward_dist``   — paper Algorithm 1/2 (call inside shard_map,
                             X sharded along x, spectral weights along k_y),
  * ``fno_forward_dist_31``— Grady et al. [31] baseline schedule (truncation
                             AFTER the repartition; communication-heavy),
so distributed-vs-serial equivalence is testable to numerical precision.

Architecture (paper Alg. 1): 1x1-conv encoder -> n_blocks x [spectral conv
+ 1x1 bypass, GELU] -> 2-layer decoder. Spectral weights are complex64 and
dominate memory (as in the paper, where the FNO fills 80% of an 80GB A100).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common import compat
from repro.core import dfft
from repro.core.dfft import BDIM, CDIM, XDIM, YDIM, ZDIM, TDIM
from repro.kernels.spectral_conv import (
    cached_weight_planes,
    spectral_apply,
    spectral_apply_fused,
    spectral_apply_fused_add,
    spectral_static_contribution,
)


@dataclasses.dataclass(frozen=True)
class FNOConfig:
    grid: Tuple[int, int, int, int]  # (nx, ny, nz, nt) of the solution tensor
    modes: Tuple[int, int, int, int]  # (mx, my, mz, mt); 2m kept per full dim
    width: int = 32
    in_channels: int = 1
    out_channels: int = 1
    n_blocks: int = 4
    decoder_dim: int = 128
    # Compute dtype for pointwise/conv ops; the FFT path is always f32.
    dtype: jnp.dtype = jnp.float32
    # Route the spectral conv through the fused Pallas kernel (truncate +
    # complex channel-mix + pad in one HBM pass); equivalence-gated against
    # the unfused path in tests/distributed_checks.py.
    use_pallas: bool = False
    # Channel-chunk the distributed FFT pipelines so each chunk's
    # all-to-all overlaps the next chunk's local FFTs (bit-identical; >1
    # only helps under a latency-hiding scheduler, see launch.devices).
    comm_chunks: int = 1
    remat: bool = True        # checkpoint each FNO block (A100-80GB -> v5e-16GB)

    @property
    def mode_shape(self) -> Tuple[int, int, int, int]:
        mx, my, mz, mt = self.modes
        return (2 * mx, 2 * my, 2 * mz, mt)

    def validate_for_parallelism(self, n_shards: int) -> None:
        nx = self.grid[0]
        two_my = 2 * self.modes[1]
        if nx % n_shards:
            raise ValueError(f"nx={nx} not divisible by {n_shards} shards")
        if two_my % n_shards:
            raise ValueError(f"2*my={two_my} not divisible by {n_shards} shards")
        self._validate_modes_fit()

    def validate_for_parallelism_2d(self, n_x: int, n_y: int) -> None:
        """Pencil decomposition: x sharded n_x ways, y sharded n_y ways.

        The two repartitions move the x-shard onto the truncated y dim and
        the y-shard onto the truncated z dim, hence the 2my/2mz constraints.
        """
        nx, ny = self.grid[0], self.grid[1]
        two_my, two_mz = 2 * self.modes[1], 2 * self.modes[2]
        if nx % n_x:
            raise ValueError(f"nx={nx} not divisible by {n_x} x-shards")
        if two_my % n_x:
            raise ValueError(f"2*my={two_my} not divisible by {n_x} x-shards")
        if ny % n_y:
            raise ValueError(f"ny={ny} not divisible by {n_y} y-shards")
        if two_mz % n_y:
            raise ValueError(f"2*mz={two_mz} not divisible by {n_y} y-shards")
        self._validate_modes_fit()

    def _validate_modes_fit(self) -> None:
        mx, my, mz, mt = self.modes
        nx, ny, nz, nt = self.grid
        if 2 * mx > nx or 2 * my > ny or 2 * mz > nz or mt > nt // 2 + 1:
            raise ValueError(f"modes {self.modes} exceed grid {self.grid}")


def init_params(key: jax.Array, cfg: FNOConfig) -> dict:
    """Initialize the FNO parameter pytree (block params stacked for scan)."""
    keys = jax.random.split(key, 6)
    w = cfg.width
    kshape = cfg.mode_shape
    scale = 1.0 / (w * w)
    spec_shape = (cfg.n_blocks, w, w) + kshape

    def uniform(k, shape, scale, dtype=jnp.float32):
        return jax.random.uniform(k, shape, dtype, -1.0, 1.0) * scale

    kr, ki = jax.random.split(keys[2])
    return {
        "encoder": {
            "w": uniform(keys[0], (cfg.in_channels, w), (1.0 / cfg.in_channels) ** 0.5),
            "b": jnp.zeros((w,), jnp.float32),
        },
        "blocks": {
            # complex64 spectral weights, the memory-dominant tensor
            "w_spec": (
                uniform(kr, spec_shape, scale) + 1j * uniform(ki, spec_shape, scale)
            ).astype(jnp.complex64),
            "w_bypass": uniform(keys[3], (cfg.n_blocks, w, w), (1.0 / w) ** 0.5),
            "b_bypass": jnp.zeros((cfg.n_blocks, w), jnp.float32),
        },
        "decoder": {
            "w1": uniform(keys[4], (w, cfg.decoder_dim), (1.0 / w) ** 0.5),
            "b1": jnp.zeros((cfg.decoder_dim,), jnp.float32),
            "w2": uniform(keys[5], (cfg.decoder_dim, cfg.out_channels), (1.0 / cfg.decoder_dim) ** 0.5),
            "b2": jnp.zeros((cfg.out_channels,), jnp.float32),
        },
    }


def param_specs(mesh: Mesh, model_axis="model", *, planes: bool = False) -> dict:
    """PartitionSpecs: spectral weights sharded along k_y (paper Alg. 2);
    encoder/decoder/bypass replicated (the paper's broadcast B).

    ``model_axis`` may be a single axis name (1-D: shard k_y), a pair
    (2-D pencil: shard k_y by the x-mesh axis and k_z by the y-mesh axis —
    the dims each shard lands on after the pencil forward's repartitions),
    or None (pure data parallelism: everything replicated).

    ``planes=True`` describes the plane-cached params tree
    (``params_with_planes``): ``w_spec`` replaced by float32
    ``w_spec_re``/``w_spec_im`` leaves. The planes keep the mode dims
    unflattened, so they take the SAME spec as the complex original.
    """
    del mesh
    if model_axis is None:
        w_spec = P()
    elif isinstance(model_axis, (tuple, list)):
        ax_x, ax_y = model_axis
        w_spec = P(None, None, None, None, ax_x, ax_y, None)
    else:
        # [n_blocks, ci, co, kx, ky, kz, kt] -> shard ky
        w_spec = P(None, None, None, None, model_axis, None, None)
    if planes:
        spec_leaves = {"w_spec_re": w_spec, "w_spec_im": w_spec}
    else:
        spec_leaves = {"w_spec": w_spec}
    return {
        "encoder": {"w": P(), "b": P()},
        "blocks": {
            **spec_leaves,
            "w_bypass": P(),
            "b_bypass": P(),
        },
        "decoder": {"w1": P(), "b1": P(), "w2": P(), "b2": P()},
    }


def params_with_planes(params: dict) -> dict:
    """Replace the complex ``w_spec`` with cached float32 re/im planes.

    For frozen params (serving): the re/im split the Pallas kernels need
    is computed ONCE per checkpoint (via the weight-plane cache) instead
    of once per block per rollout step, and the complex original is
    dropped from the tree so device memory is not doubled. The planes
    shard with the same PartitionSpecs (``param_specs(..., planes=True)``).
    """
    blocks = dict(params["blocks"])
    if "w_spec" not in blocks:
        return params
    w = blocks.pop("w_spec")
    wr, wi = cached_weight_planes(w)
    blocks["w_spec_re"] = wr
    blocks["w_spec_im"] = wi
    return {**params, "blocks": blocks}


def params_without_planes(params: dict) -> dict:
    """Inverse of ``params_with_planes``: recombine planes to complex
    ``w_spec`` (used by the serving --verify oracle, which replays through
    the plain serial forward)."""
    blocks = dict(params["blocks"])
    if "w_spec" in blocks:
        return params
    wr = blocks.pop("w_spec_re")
    wi = blocks.pop("w_spec_im")
    blocks["w_spec"] = wr + 1j * wi
    return {**params, "blocks": blocks}


def _block_weights(blk: dict):
    """Per-block spectral weights from a scan slice of params['blocks']:
    the complex ``w_spec`` or, for plane-cached params, the (re, im)
    tuple both ``spectral_apply`` and ``spectral_apply_fused`` accept."""
    if "w_spec" in blk:
        return blk["w_spec"]
    return (blk["w_spec_re"], blk["w_spec_im"])


def _conv1x1(x: jax.Array, w: jax.Array, b: Optional[jax.Array]) -> jax.Array:
    """Channel-mixing 1x1 conv on [b, c, x, y, z, t]."""
    y = jnp.einsum("bixyzt,io->boxyzt", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)[None, :, None, None, None, None]
    return y


def _encoder(params: dict, x: jax.Array, cfg: FNOConfig) -> jax.Array:
    x = x.astype(cfg.dtype)
    return jax.nn.gelu(_conv1x1(x, params["encoder"]["w"], params["encoder"]["b"]))


def encoder_prelift(params: dict, x: jax.Array, cfg: FNOConfig, channels=None) -> jax.Array:
    """Partial pre-activation lift of a channel SLICE of the input.

    The encoder's 1x1 conv is linear in x, so the lift of the static
    (geomodel) channels and the lift of the dynamic (well/state) channels
    can be computed independently and summed before bias + GELU. This is
    what lets serving cache the static-channel lift across requests and
    rollout steps (``serve.geomodel_cache``): precompute
    ``encoder_prelift(params, x_static, cfg, slice(0, n_static))`` once per
    geomodel, then only the dynamic slice is lifted per request.

    ``x``: [b, c_sub, nx, ny, nz, nt] where c_sub matches ``channels``
    (a slice into ``in_channels``; default: all). Returns the
    pre-activation partial sum [b, width, ...] — no bias, no GELU.
    """
    w = params["encoder"]["w"]
    if channels is not None:
        w = w[channels]
    x = x.astype(cfg.dtype)
    return jnp.einsum("bixyzt,io->boxyzt", x, w.astype(x.dtype))


def _encoder_from_prelift(params: dict, pre: jax.Array, cfg: FNOConfig) -> jax.Array:
    """bias + GELU over a (summed) pre-activation lift."""
    b = params["encoder"]["b"].astype(pre.dtype)
    return jax.nn.gelu(pre + b[None, :, None, None, None, None])


def _decoder(params: dict, x: jax.Array, cfg: FNOConfig) -> jax.Array:
    d = params["decoder"]
    h = jax.nn.gelu(_conv1x1(x, d["w1"], d["b1"]))
    out = _conv1x1(h, d["w2"], d["b2"])
    return out.astype(jnp.float32)


def _bypass(x, w_b, b_b):
    return _conv1x1(x, w_b, b_b)


# ---------------------------------------------------------------------------
# Serial oracle.
# ---------------------------------------------------------------------------

def fno_block(x, w_spec, w_b, b_b, cfg: FNOConfig, *, add_kept=None, bypass_x=None):
    """Serial FNO block: irfftn(pad(W . trunc(rfftn(x)))) + bypass, GELU.

    With ``use_pallas`` the S / W· / S^T epilogue happens inside the fused
    kernel, so the FFT layer neither truncates nor pads — the mode tensor
    crosses HBM once instead of four times.

    Deep-split serving (``fno_forward_deep_split``) passes ``add_kept``, a
    cached kept-mode contribution summed into the spectral output before
    the inverse transform, and ``bypass_x``, the full activation the 1x1
    bypass runs on when ``x`` is only the dynamic remainder.
    """
    if cfg.use_pallas:
        nx, ny, nz, nt = cfg.grid
        xf = dfft.serial_forward(x, cfg.modes, truncate=False)
        if add_kept is None:
            yf = spectral_apply_fused(xf, w_spec, (nx, ny, nz), t_out=nt // 2 + 1)
        else:
            yf = spectral_apply_fused_add(
                xf, w_spec, add_kept, (nx, ny, nz), t_out=nt // 2 + 1
            )
        y = dfft.serial_adjoint(yf, cfg.grid, out_dtype=cfg.dtype, pre_padded=True)
    else:
        xf = dfft.serial_forward(x, cfg.modes)
        yf = spectral_apply(xf, w_spec, use_pallas=False)
        if add_kept is not None:
            yf = yf + add_kept.astype(yf.dtype)
        y = dfft.serial_adjoint(yf, cfg.grid, out_dtype=cfg.dtype)
    xb = x if bypass_x is None else bypass_x
    return jax.nn.gelu(y + _bypass(xb, w_b, b_b))


def _run_blocks(params: dict, h: jax.Array, cfg: FNOConfig, block_apply):
    """Shared tail of every forward: scan the FNO blocks, then decode.
    ``block_apply(h, blk)`` applies one block's params to the hidden state."""

    def body(h, blk):
        return block_apply(h, blk), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return _decoder(params, h, cfg)


def fno_forward(params: dict, x: jax.Array, cfg: FNOConfig) -> jax.Array:
    """Single-device forward. x: [b, c_in, nx, ny, nz, nt] -> [b, c_out, ...]."""
    h = _encoder(params, x, cfg)
    return _run_blocks(
        params, h, cfg,
        lambda h, blk: fno_block(h, _block_weights(blk), blk["w_bypass"], blk["b_bypass"], cfg),
    )


def fno_forward_split(
    params: dict, pre_static: jax.Array, x_dyn: jax.Array, cfg: FNOConfig, n_static: int
) -> jax.Array:
    """Single-device forward from a precomputed static-channel prelift.

    ``pre_static``: [b, width, ...] — the cached partial lift of the first
    ``n_static`` input channels (``encoder_prelift`` over the NORMALIZED
    static channels). ``x_dyn``: [b, in_channels - n_static, ...] — the
    normalized dynamic channels, lifted here. Equal to ``fno_forward`` on
    the concatenated input up to float-summation order (the cold and warm
    cache paths both go through THIS function, so they are bit-identical
    to each other).
    """
    pre = pre_static.astype(cfg.dtype) + encoder_prelift(
        params, x_dyn, cfg, slice(n_static, None)
    )
    h = _encoder_from_prelift(params, pre, cfg)
    return _run_blocks(
        params, h, cfg,
        lambda h, blk: fno_block(h, _block_weights(blk), blk["w_bypass"], blk["b_bypass"], cfg),
    )


def spectral_prelift(params: dict, pre_static: jax.Array, cfg: FNOConfig, *, block: int = 0):
    """Static prefix of the FIRST spectral block, computed once per geomodel.

    The block-input split: write the first hidden state as
    ``h = h_static + h_rem`` with ``h_static = GELU(pre_static + b)`` a pure
    function of the cached static-channel prelift. FFT -> truncate -> mix is
    linear, so block 0's kept-mode output is
    ``W . S(h_rem)  +  W . S(h_static)`` — and the second term (and its
    spectrum) can be cached alongside the prelift and summed into the
    dynamic remainder's pre-activation on every warm request
    (``fno_forward_deep_split``). The nonlinearity after block 0 stops the
    split from going deeper.

    ``pre_static``: [b, width, nx, ny, nz, nt] (or unbatched [width, ...]).
    Returns ``(spectra, contribution)``: the truncated kept-mode spectrum
    S(h_static) [.., width, 2mx, 2my, 2mz, mt] and the weight-mixed
    contribution W_block . S(h_static) of the same shape — cache levels L3
    and L4 of ``serve.geomodel_cache``.
    """
    unbatched = pre_static.ndim == 5
    if unbatched:
        pre_static = pre_static[None]
    h_s = _encoder_from_prelift(params, pre_static.astype(cfg.dtype), cfg)
    spectra = dfft.serial_forward(h_s, cfg.modes)
    blk = jax.tree.map(lambda a: a[block], params["blocks"])
    contrib = spectral_static_contribution(spectra, _block_weights(blk))
    if unbatched:
        spectra, contrib = spectra[0], contrib[0]
    return spectra, contrib


def _fno_forward_deep_impl(params, pre_static, x_dyn, cfg, n_static, block_first, block_rest):
    """Shared deep-split body: rebuild the full first hidden state, run
    block 0 on the dynamic REMAINDER ``h - h_static`` (its static kept-mode
    term arrives precomputed via ``block_first``'s closure), then the
    remaining blocks unchanged."""
    pre_s = pre_static.astype(cfg.dtype)
    pre = pre_s + encoder_prelift(params, x_dyn, cfg, slice(n_static, None))
    h_full = _encoder_from_prelift(params, pre, cfg)
    h_static = _encoder_from_prelift(params, pre_s, cfg)
    blocks = params["blocks"]
    blk0 = jax.tree.map(lambda a: a[0], blocks)
    h = block_first(h_full - h_static, blk0, h_full)
    rest = {**params, "blocks": jax.tree.map(lambda a: a[1:], blocks)}
    return _run_blocks(rest, h, cfg, block_rest)


def fno_forward_deep_split(
    params: dict,
    contrib: jax.Array,
    pre_static: jax.Array,
    x_dyn: jax.Array,
    cfg: FNOConfig,
    n_static: int,
) -> jax.Array:
    """Single-device forward from a cached prelift AND a cached first-block
    static contribution (``spectral_prelift``).

    ``contrib``: [b, width, 2mx, 2my, 2mz, mt] complex — the kept-mode
    static contribution ``W_0 . S(h_static)``. Mathematically equal to
    ``fno_forward_split`` (hence ``fno_forward``) up to float-summation
    order; cold and warm cache paths both go through THIS function with
    identical host-computed operands, so they are bit-identical to each
    other.
    """
    ck = contrib.astype(jnp.complex64)

    def first(h_rem, blk, h_full):
        return fno_block(
            h_rem, _block_weights(blk), blk["w_bypass"], blk["b_bypass"], cfg,
            add_kept=ck, bypass_x=h_full,
        )

    def rest(h, blk):
        return fno_block(
            h, _block_weights(blk), blk["w_bypass"], blk["b_bypass"], cfg
        )

    return _fno_forward_deep_impl(
        params, pre_static, x_dyn, cfg, n_static, first, rest
    )


# ---------------------------------------------------------------------------
# Distributed forward (paper Algorithm 1 + 2). Call INSIDE shard_map with:
#   x       sharded P(dp_axes, None, model_axis, None, None, None)
#   w_spec  sharded P(None, None, None, None, model_axis, None, None)
#   everything else replicated.
# ---------------------------------------------------------------------------

def fno_block_dist(x, w_spec, w_b, b_b, cfg: FNOConfig, axis_name: str,
                   *, add_kept=None, bypass_x=None):
    """Paper Alg. 2: local F/S over yzt, R_{x->y}, F/S over x, local spectral
    multiply (weights pre-sharded along k_y), adjoint path back.

    Fused path: y/z/t are truncated before the repartition as always (the
    paper's comm optimization), but S_x / S_x^T move into the kernel —
    the only dims still full-size at the kernel are the post-repartition
    x extent, exactly the three extra HBM passes the fusion removes.

    ``add_kept`` is the LOCAL shard of a cached kept-mode contribution
    ([b, co, 2mx, 2my/P, 2mz, mt] — same k_y sharding as ``w_spec``, see
    ``contrib_spec``); ``bypass_x`` as in ``fno_block``.
    """
    if cfg.use_pallas:
        xf = dfft.dist_forward(
            x, cfg.modes, axis_name, trunc_x=False, comm_chunks=cfg.comm_chunks
        )
        if add_kept is None:
            yf = spectral_apply_fused(xf, w_spec, (cfg.grid[0], None, None))
        else:
            yf = spectral_apply_fused_add(
                xf, w_spec, add_kept, (cfg.grid[0], None, None)
            )
        y = dfft.dist_adjoint(
            yf, cfg.grid, axis_name, out_dtype=cfg.dtype,
            pad_x=False, comm_chunks=cfg.comm_chunks,
        )
    else:
        xf = dfft.dist_forward(x, cfg.modes, axis_name, comm_chunks=cfg.comm_chunks)
        yf = spectral_apply(xf, w_spec, use_pallas=False)
        if add_kept is not None:
            yf = yf + add_kept.astype(yf.dtype)
        y = dfft.dist_adjoint(
            yf, cfg.grid, axis_name, out_dtype=cfg.dtype,
            comm_chunks=cfg.comm_chunks,
        )
    xb = x if bypass_x is None else bypass_x
    return jax.nn.gelu(y + _bypass(xb, w_b, b_b))


def fno_block_dist_31(x, w_spec, w_b, b_b, cfg: FNOConfig, axis_name: str,
                      *, add_kept=None, bypass_x=None):
    """Grady et al. [31] schedule: repartition the UNtruncated spectrum."""
    nx, ny, nz, nt = cfg.grid
    if cfg.use_pallas:
        xf = dfft.dist_forward_untruncated(
            x, cfg.modes, axis_name, trunc_xzt=False,
            comm_chunks=cfg.comm_chunks,
        )
        if add_kept is None:
            yf = spectral_apply_fused(
                xf, w_spec, (nx, None, nz), t_out=nt // 2 + 1
            )
        else:
            yf = spectral_apply_fused_add(
                xf, w_spec, add_kept, (nx, None, nz), t_out=nt // 2 + 1
            )
        y = dfft.dist_adjoint_untruncated(
            yf, cfg.grid, axis_name, out_dtype=cfg.dtype,
            pad_xzt=False, comm_chunks=cfg.comm_chunks,
        )
    else:
        xf = dfft.dist_forward_untruncated(
            x, cfg.modes, axis_name, comm_chunks=cfg.comm_chunks
        )
        yf = spectral_apply(xf, w_spec, use_pallas=False)
        if add_kept is not None:
            yf = yf + add_kept.astype(yf.dtype)
        y = dfft.dist_adjoint_untruncated(
            yf, cfg.grid, axis_name, out_dtype=cfg.dtype,
            comm_chunks=cfg.comm_chunks,
        )
    xb = x if bypass_x is None else bypass_x
    return jax.nn.gelu(y + _bypass(xb, w_b, b_b))


def fno_block_dist_eager(x, w_spec, w_b, b_b, cfg: FNOConfig, axis_name: str,
                         *, add_kept=None, bypass_x=None):
    """Beyond-paper: per-dim eager truncation (bit-equivalent, cheaper FFTs)."""
    if cfg.use_pallas:
        xf = dfft.dist_forward_eager(
            x, cfg.modes, axis_name, trunc_x=False, comm_chunks=cfg.comm_chunks
        )
        if add_kept is None:
            yf = spectral_apply_fused(xf, w_spec, (cfg.grid[0], None, None))
        else:
            yf = spectral_apply_fused_add(
                xf, w_spec, add_kept, (cfg.grid[0], None, None)
            )
        y = dfft.dist_adjoint_eager(
            yf, cfg.grid, axis_name, out_dtype=cfg.dtype,
            pad_x=False, comm_chunks=cfg.comm_chunks,
        )
    else:
        xf = dfft.dist_forward_eager(
            x, cfg.modes, axis_name, comm_chunks=cfg.comm_chunks
        )
        yf = spectral_apply(xf, w_spec, use_pallas=False)
        if add_kept is not None:
            yf = yf + add_kept.astype(yf.dtype)
        y = dfft.dist_adjoint_eager(
            yf, cfg.grid, axis_name, out_dtype=cfg.dtype,
            comm_chunks=cfg.comm_chunks,
        )
    xb = x if bypass_x is None else bypass_x
    return jax.nn.gelu(y + _bypass(xb, w_b, b_b))


def fno_block_dist_2d(x, w_spec, w_b, b_b, cfg: FNOConfig, axis_names,
                      *, add_kept=None, bypass_x=None):
    """2-D pencil block: x sharded along both x and y, spectral weights
    sharded along k_y x k_z (matching dist_forward_2d's output layout)."""
    if cfg.use_pallas:
        xf = dfft.dist_forward_2d(
            x, cfg.modes, axis_names, trunc_x=False, comm_chunks=cfg.comm_chunks
        )
        if add_kept is None:
            yf = spectral_apply_fused(xf, w_spec, (cfg.grid[0], None, None))
        else:
            yf = spectral_apply_fused_add(
                xf, w_spec, add_kept, (cfg.grid[0], None, None)
            )
        y = dfft.dist_adjoint_2d(
            yf, cfg.grid, axis_names, out_dtype=cfg.dtype,
            pad_x=False, comm_chunks=cfg.comm_chunks,
        )
    else:
        xf = dfft.dist_forward_2d(
            x, cfg.modes, axis_names, comm_chunks=cfg.comm_chunks
        )
        yf = spectral_apply(xf, w_spec, use_pallas=False)
        if add_kept is not None:
            yf = yf + add_kept.astype(yf.dtype)
        y = dfft.dist_adjoint_2d(
            yf, cfg.grid, axis_names, out_dtype=cfg.dtype,
            comm_chunks=cfg.comm_chunks,
        )
    xb = x if bypass_x is None else bypass_x
    return jax.nn.gelu(y + _bypass(xb, w_b, b_b))


def fno_block_dist_2d_eager(x, w_spec, w_b, b_b, cfg: FNOConfig, axis_names,
                            *, add_kept=None, bypass_x=None):
    """2-D pencil block with per-dim eager truncation."""
    if cfg.use_pallas:
        xf = dfft.dist_forward_2d_eager(
            x, cfg.modes, axis_names, trunc_x=False, comm_chunks=cfg.comm_chunks
        )
        if add_kept is None:
            yf = spectral_apply_fused(xf, w_spec, (cfg.grid[0], None, None))
        else:
            yf = spectral_apply_fused_add(
                xf, w_spec, add_kept, (cfg.grid[0], None, None)
            )
        y = dfft.dist_adjoint_2d_eager(
            yf, cfg.grid, axis_names, out_dtype=cfg.dtype,
            pad_x=False, comm_chunks=cfg.comm_chunks,
        )
    else:
        xf = dfft.dist_forward_2d_eager(
            x, cfg.modes, axis_names, comm_chunks=cfg.comm_chunks
        )
        yf = spectral_apply(xf, w_spec, use_pallas=False)
        if add_kept is not None:
            yf = yf + add_kept.astype(yf.dtype)
        y = dfft.dist_adjoint_2d_eager(
            yf, cfg.grid, axis_names, out_dtype=cfg.dtype,
            comm_chunks=cfg.comm_chunks,
        )
    xb = x if bypass_x is None else bypass_x
    return jax.nn.gelu(y + _bypass(xb, w_b, b_b))


def _fno_forward_dist_impl(params, x, cfg, axis_name, block_fn):
    # Encoder/decoder weights are replicated (paper's broadcast B); the
    # convs contract channels only, so they are embarrassingly parallel
    # over the sharded x dim (paper Alg. 1).
    h = _encoder(params, x, cfg)
    return _run_blocks(
        params, h, cfg,
        lambda h, blk: block_fn(
            h, _block_weights(blk), blk["w_bypass"], blk["b_bypass"], cfg, axis_name
        ),
    )


def _fno_forward_dist_split_impl(params, pre_static, x_dyn, cfg, n_static, axis_name, block_fn):
    # Split-encoder distributed forward: the prelift add and the dynamic
    # channel contraction are pointwise over the sharded spatial dims, so
    # they need no communication — only the blocks do (as in the fused path).
    pre = pre_static.astype(cfg.dtype) + encoder_prelift(
        params, x_dyn, cfg, slice(n_static, None)
    )
    h = _encoder_from_prelift(params, pre, cfg)
    return _run_blocks(
        params, h, cfg,
        lambda h, blk: block_fn(
            h, _block_weights(blk), blk["w_bypass"], blk["b_bypass"], cfg, axis_name
        ),
    )


def fno_forward_dist(params, x, cfg: FNOConfig, axis_name: str = "model"):
    return _fno_forward_dist_impl(params, x, cfg, axis_name, fno_block_dist)


def fno_forward_dist_31(params, x, cfg: FNOConfig, axis_name: str = "model"):
    return _fno_forward_dist_impl(params, x, cfg, axis_name, fno_block_dist_31)


def fno_forward_dist_eager(params, x, cfg: FNOConfig, axis_name: str = "model"):
    return _fno_forward_dist_impl(params, x, cfg, axis_name, fno_block_dist_eager)


def fno_forward_dist_2d(params, x, cfg: FNOConfig, axis_names=("mx", "my")):
    return _fno_forward_dist_impl(params, x, cfg, tuple(axis_names), fno_block_dist_2d)


def fno_forward_dist_2d_eager(params, x, cfg: FNOConfig, axis_names=("mx", "my")):
    return _fno_forward_dist_impl(
        params, x, cfg, tuple(axis_names), fno_block_dist_2d_eager
    )


_VARIANTS = {
    "paper": fno_forward_dist,
    "grady31": fno_forward_dist_31,
    "eager": fno_forward_dist_eager,
}

_VARIANTS_2D = {
    "paper": fno_forward_dist_2d,
    "eager": fno_forward_dist_2d_eager,
}

_BLOCKS = {
    "paper": fno_block_dist,
    "grady31": fno_block_dist_31,
    "eager": fno_block_dist_eager,
}

_BLOCKS_2D = {
    "paper": fno_block_dist_2d,
    "eager": fno_block_dist_2d_eager,
}


def input_spec(dp_axes, model_axis) -> P:
    """PartitionSpec of the solution tensor [b, c, x, y, z, t]: batch over
    the data axes, x (and y, for a pencil pair) over the model axes. The
    single source of truth for make_dist_forward's in/out layout — reuse it
    wherever explicit in_shardings must match the shard_map'd forward.
    ``model_axis=None`` shards the batch dim only (pure data parallelism)."""
    if model_axis is None:
        return P(dp_axes, None, None, None, None, None)
    if isinstance(model_axis, (tuple, list)):
        ax_x, ax_y = model_axis
        return P(dp_axes, None, ax_x, ax_y, None, None)
    return P(dp_axes, None, model_axis, None, None, None)


def make_dist_forward(
    mesh: Mesh,
    cfg: FNOConfig,
    *,
    dp_axes=("data",),
    model_axis="model",
    variant: str = "paper",
    planes: bool = False,
):
    """Build the shard_map'd distributed forward for a mesh.

    ``model_axis``: a single mesh-axis name shards the solution along x
    (paper Alg. 2); a PAIR of names, e.g. ``("mx", "my")``, selects the 2-D
    pencil decomposition (x sharded by the first axis, y by the second),
    lifting the 1-D parallelism cap from nx/2mx to (nx/2mx)*(ny/2my).

    variant: "paper" (truncate-then-repartition), "grady31" (the [31]
    baseline, 1-D only), or "eager" (beyond-paper per-dim truncation).

    ``planes=True``: the params tree carries plane-cached spectral weights
    (``params_with_planes``) — the shard_map in_specs must match that tree.
    """
    if isinstance(model_axis, (tuple, list)):
        model_axes = tuple(model_axis)
        if len(model_axes) != 2:
            raise ValueError(f"expected 2 model axes, got {model_axes}")
        cfg.validate_for_parallelism_2d(*(mesh.shape[a] for a in model_axes))
        if variant not in _VARIANTS_2D:
            raise ValueError(
                f"variant {variant!r} has no 2-D schedule; pick from "
                f"{sorted(_VARIANTS_2D)}"
            )
        fwd = _VARIANTS_2D[variant]
        x_spec = input_spec(dp_axes, model_axes)
        p_specs = param_specs(mesh, model_axes, planes=planes)

        def shard_fwd(params, x):
            return fwd(params, x, cfg, model_axes)

    else:
        cfg.validate_for_parallelism(mesh.shape[model_axis])
        fwd = _VARIANTS[variant]
        x_spec = input_spec(dp_axes, model_axis)
        p_specs = param_specs(mesh, model_axis, planes=planes)

        def shard_fwd(params, x):
            return fwd(params, x, cfg, model_axis)

    return compat.shard_map(
        shard_fwd, mesh, (p_specs, x_spec), x_spec
    )


def make_dist_forward_split(
    mesh: Mesh,
    cfg: FNOConfig,
    n_static: int,
    *,
    dp_axes=("data",),
    model_axis="model",
    variant: str = "paper",
    planes: bool = False,
):
    """shard_map'd distributed forward taking (params, pre_static, x_dyn).

    ``pre_static`` [b, width, ...] and ``x_dyn`` [b, c_dyn, ...] share the
    solution tensor's layout (``input_spec``): the channel dim is never
    sharded, so the same spec covers both. See ``fno_forward_split``.
    """
    if isinstance(model_axis, (tuple, list)):
        model_axes = tuple(model_axis)
        if len(model_axes) != 2:
            raise ValueError(f"expected 2 model axes, got {model_axes}")
        cfg.validate_for_parallelism_2d(*(mesh.shape[a] for a in model_axes))
        if variant not in _BLOCKS_2D:
            raise ValueError(
                f"variant {variant!r} has no 2-D schedule; pick from "
                f"{sorted(_BLOCKS_2D)}"
            )
        block_fn, axis = _BLOCKS_2D[variant], model_axes
        x_spec = input_spec(dp_axes, model_axes)
        p_specs = param_specs(mesh, model_axes, planes=planes)
    else:
        cfg.validate_for_parallelism(mesh.shape[model_axis])
        block_fn, axis = _BLOCKS[variant], model_axis
        x_spec = input_spec(dp_axes, model_axis)
        p_specs = param_specs(mesh, model_axis, planes=planes)

    def shard_fwd(params, pre_static, x_dyn):
        return _fno_forward_dist_split_impl(
            params, pre_static, x_dyn, cfg, n_static, axis, block_fn
        )

    return compat.shard_map(
        shard_fwd, mesh, (p_specs, x_spec, x_spec), x_spec
    )


def contrib_spec(dp_axes, model_axis) -> P:
    """PartitionSpec of the cached kept-mode contribution
    [b, co, 2mx, 2my, 2mz, mt]: batch over the data axes, k_y over the
    model axis (matching ``w_spec``'s sharding, since the contribution is a
    per-mode product with it) — and k_z over the second axis of a pencil
    pair. ``model_axis=None`` shards the batch dim only."""
    if model_axis is None:
        return P(dp_axes, None, None, None, None, None)
    if isinstance(model_axis, (tuple, list)):
        ax_x, ax_y = model_axis
        return P(dp_axes, None, None, ax_x, ax_y, None)
    return P(dp_axes, None, None, model_axis, None, None)


def make_dist_forward_deep_split(
    mesh: Mesh,
    cfg: FNOConfig,
    n_static: int,
    *,
    dp_axes=("data",),
    model_axis="model",
    variant: str = "paper",
    planes: bool = False,
):
    """shard_map'd distributed forward taking
    ``(params, contrib, pre_static, x_dyn)``.

    ``contrib`` is the GLOBAL [b, width, 2mx, 2my, 2mz, mt] kept-mode
    static contribution (``spectral_prelift``), sharded per
    ``contrib_spec`` so each shard holds exactly the k_y (x k_z) modes its
    ``w_spec`` shard would have produced. See ``fno_forward_deep_split``.
    """
    if isinstance(model_axis, (tuple, list)):
        model_axes = tuple(model_axis)
        if len(model_axes) != 2:
            raise ValueError(f"expected 2 model axes, got {model_axes}")
        cfg.validate_for_parallelism_2d(*(mesh.shape[a] for a in model_axes))
        if variant not in _BLOCKS_2D:
            raise ValueError(
                f"variant {variant!r} has no 2-D schedule; pick from "
                f"{sorted(_BLOCKS_2D)}"
            )
        block_fn, axis = _BLOCKS_2D[variant], model_axes
        x_spec = input_spec(dp_axes, model_axes)
        c_spec = contrib_spec(dp_axes, model_axes)
        p_specs = param_specs(mesh, model_axes, planes=planes)
    else:
        cfg.validate_for_parallelism(mesh.shape[model_axis])
        block_fn, axis = _BLOCKS[variant], model_axis
        x_spec = input_spec(dp_axes, model_axis)
        c_spec = contrib_spec(dp_axes, model_axis)
        p_specs = param_specs(mesh, model_axis, planes=planes)

    def shard_fwd(params, contrib, pre_static, x_dyn):
        ck = contrib.astype(jnp.complex64)

        def first(h_rem, blk, h_full):
            return block_fn(
                h_rem, _block_weights(blk), blk["w_bypass"], blk["b_bypass"],
                cfg, axis, add_kept=ck, bypass_x=h_full,
            )

        def rest(h, blk):
            return block_fn(
                h, _block_weights(blk), blk["w_bypass"], blk["b_bypass"],
                cfg, axis,
            )

        return _fno_forward_deep_impl(
            params, pre_static, x_dyn, cfg, n_static, first, rest
        )

    return compat.shard_map(
        shard_fwd, mesh, (p_specs, c_spec, x_spec, x_spec), x_spec
    )


def deep_split_forward_and_specs(
    mesh: Mesh,
    cfg: FNOConfig,
    n_static: int,
    *,
    dp_axes=("data",),
    model_axis=None,
    variant: str = "paper",
    planes: bool = False,
):
    """``split_forward_and_specs`` for the deep (first-block) split: the
    returned ``forward(params, contrib, pre_static, x_dyn)`` additionally
    consumes the cached kept-mode static contribution. Returns
    ``(forward, x_spec, c_spec, p_specs)`` — ``c_spec`` is the
    contribution's layout (``contrib_spec``)."""
    x_spec = input_spec(dp_axes, model_axis)
    c_spec = contrib_spec(dp_axes, model_axis)
    p_specs = param_specs(mesh, model_axis, planes=planes)
    if model_axis is None:
        def forward(params, contrib, pre_static, x_dyn):
            return fno_forward_deep_split(
                params, contrib, pre_static, x_dyn, cfg, n_static
            )
    else:
        forward = make_dist_forward_deep_split(
            mesh, cfg, n_static, dp_axes=dp_axes, model_axis=model_axis,
            variant=variant, planes=planes,
        )
    return forward, x_spec, c_spec, p_specs


def split_forward_and_specs(
    mesh: Mesh,
    cfg: FNOConfig,
    n_static: int,
    *,
    dp_axes=("data",),
    model_axis=None,
    variant: str = "paper",
    planes: bool = False,
):
    """``forward_and_specs`` for the split encoder: the returned
    ``forward(params, pre_static, x_dyn)`` consumes a precomputed (cached)
    static-channel prelift plus the normalized dynamic channels. Layouts
    are identical to the fused path (channel dim unsharded), so the same
    ``x_spec`` serves both operands.
    """
    x_spec = input_spec(dp_axes, model_axis)
    p_specs = param_specs(mesh, model_axis, planes=planes)
    if model_axis is None:
        def forward(params, pre_static, x_dyn):
            return fno_forward_split(params, pre_static, x_dyn, cfg, n_static)
    else:
        forward = make_dist_forward_split(
            mesh, cfg, n_static, dp_axes=dp_axes, model_axis=model_axis,
            variant=variant, planes=planes,
        )
    return forward, x_spec, p_specs


def forward_and_specs(
    mesh: Mesh,
    cfg: FNOConfig,
    *,
    dp_axes=("data",),
    model_axis=None,
    variant: str = "paper",
    planes: bool = False,
):
    """(forward, x_spec, p_specs) for a mesh: the single source of truth for
    how an FNO batch and its params are laid out, shared by the training
    driver and the serving runner (instead of each duplicating the
    serial-vs-distributed branch and the spec plumbing).

    ``model_axis=None`` returns the serial oracle (pure data parallelism:
    params replicated, batch sharded over ``dp_axes``); a mesh-axis name or
    a pair of names returns the shard_map'd distributed forward (paper
    Alg. 2 / 2-D pencils). ``forward(params, x)`` in all cases.

    ``planes=True``: specs and shard_map layouts for a plane-cached params
    tree (``params_with_planes``, serving only).
    """
    x_spec = input_spec(dp_axes, model_axis)
    p_specs = param_specs(mesh, model_axis, planes=planes)
    if model_axis is None:
        def forward(params, x):
            return fno_forward(params, x, cfg)
    else:
        forward = make_dist_forward(
            mesh, cfg, dp_axes=dp_axes, model_axis=model_axis, variant=variant,
            planes=planes,
        )
    return forward, x_spec, p_specs


def mse_loss(pred: jax.Array, target: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - target.astype(jnp.float32)))
