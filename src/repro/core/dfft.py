"""Distributed 4-D FFT with frequency truncation (S ∘ F and adjoints).

Implements the operators of the paper's Algorithm 2:

  forward:  S_x F_x R_{x->y} S_{yzt} F_{yzt}
  adjoint:  F_{yzt}^T S_{yzt}^T R_{x->y}^T F_x^T S_x^T

Conventions (matching the serial jnp oracle exactly):
  * data layout X[b, c, x, y, z, t], real input;
  * rFFT along the trailing time dim (real spectrum, keep first m_t bins);
  * full FFT along x, y, z: truncation keeps the m lowest positive and m
    highest (negative) frequencies -> 2m coefficients per dim (the standard
    FNO "corner" modes);
  * S^T is zero-padding back into the middle of the spectrum;
  * F^T here denotes the *inverse* FFT (the paper composes S/F with their
    adjoints such that the round trip is the identity on kept modes; using
    the unitary-scaled inverse keeps the serial and distributed paths
    bit-identical).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.common import compat
from repro.core.repartition import repartition

# Dim indices in the canonical [b, c, x, y, z, t] layout.
BDIM, CDIM, XDIM, YDIM, ZDIM, TDIM = range(6)
SPATIAL_DIMS = (XDIM, YDIM, ZDIM, TDIM)


# ---------------------------------------------------------------------------
# Truncation S and its adjoint (zero padding).
# ---------------------------------------------------------------------------

def truncate_full(x: jax.Array, axis: int, m: int) -> jax.Array:
    """Keep 2m lowest-|k| modes of a full FFT dim: [:m] and [-m:]."""
    n = x.shape[axis]
    if 2 * m > n:
        raise ValueError(f"2m={2*m} exceeds dim size {n}")
    lo = jax.lax.slice_in_dim(x, 0, m, axis=axis)
    hi = jax.lax.slice_in_dim(x, n - m, n, axis=axis)
    return jnp.concatenate([lo, hi], axis=axis)


def pad_full(x: jax.Array, axis: int, n: int) -> jax.Array:
    """Adjoint of truncate_full: zero-fill the middle back to size n."""
    two_m = x.shape[axis]
    m = two_m // 2
    lo = jax.lax.slice_in_dim(x, 0, m, axis=axis)
    hi = jax.lax.slice_in_dim(x, m, two_m, axis=axis)
    pad_shape = list(x.shape)
    pad_shape[axis] = n - two_m
    zeros = jnp.zeros(pad_shape, dtype=x.dtype)
    return jnp.concatenate([lo, zeros, hi], axis=axis)


def truncate_rfft(x: jax.Array, axis: int, m: int) -> jax.Array:
    """Keep the first m bins of an rFFT dim."""
    return jax.lax.slice_in_dim(x, 0, m, axis=axis)


def pad_rfft(x: jax.Array, axis: int, n_bins: int) -> jax.Array:
    """Adjoint of truncate_rfft: zero-pad the tail back to n_bins."""
    pad_shape = list(x.shape)
    pad_shape[axis] = n_bins - x.shape[axis]
    return jnp.concatenate([x, jnp.zeros(pad_shape, x.dtype)], axis=axis)


def truncate_modes(
    xf: jax.Array, modes: Sequence[int], axes: Sequence[int] = SPATIAL_DIMS
) -> jax.Array:
    """Truncate all spatial dims; the last axis in ``axes`` is the rFFT dim."""
    *full_axes, rfft_axis = axes
    mx = modes[: len(full_axes)]
    for axis, m in zip(full_axes, mx):
        xf = truncate_full(xf, axis, m)
    return truncate_rfft(xf, rfft_axis, modes[-1])


def pad_modes(
    xf: jax.Array,
    full_sizes: Sequence[int],
    axes: Sequence[int] = SPATIAL_DIMS,
) -> jax.Array:
    """Adjoint of truncate_modes. full_sizes includes the rFFT bin count."""
    *full_axes, rfft_axis = axes
    for axis, n in zip(full_axes, full_sizes[:-1]):
        xf = pad_full(xf, axis, n)
    return pad_rfft(xf, rfft_axis, full_sizes[-1])


# ---------------------------------------------------------------------------
# Serial oracle: S ∘ F over all four dims at once.
# ---------------------------------------------------------------------------

def serial_forward(
    x: jax.Array, modes: Sequence[int], *, truncate: bool = True
) -> jax.Array:
    """rFFT over t + 3-D FFT over (x,y,z), then truncation.

    x: real [b,c,nx,ny,nz,nt]. Equivalent to rfftn over all four dims, but
    XLA only lowers FFTs of rank <= 3, so the 4-D transform is composed
    from a 1-D rFFT and a 3-D FFT (per-axis FFTs commute).

    ``truncate=False`` returns the full spectrum — used by the fused
    Pallas path, whose kernel performs S (and S^T) itself.
    """
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=TDIM)
    xf = jnp.fft.fftn(xf, axes=(XDIM, YDIM, ZDIM))
    if truncate:
        xf = truncate_modes(xf, modes)
    return xf


def serial_adjoint(
    xf: jax.Array,
    grid: Sequence[int],
    out_dtype=jnp.float32,
    *,
    pre_padded: bool = False,
) -> jax.Array:
    """Zero-pad then inverse transform; grid is the real-space (nx,ny,nz,nt).

    Composed as 3-D iFFT over (x,y,z) + 1-D irFFT over t for the same
    rank-3 XLA limit; the 1/N scaling factors multiply to irfftn's.

    ``pre_padded=True`` means ``xf`` is already the full-size spectrum
    (the fused Pallas kernel zero-fills S^T in-kernel) — skip pad_modes.
    """
    nx, ny, nz, nt = grid
    full = xf if pre_padded else pad_modes(xf, (nx, ny, nz, nt // 2 + 1))
    full = jnp.fft.ifftn(full, axes=(XDIM, YDIM, ZDIM))
    y = jnp.fft.irfft(full, n=nt, axis=TDIM)
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# Communication/compute overlap: chunk the channel extent so each chunk's
# repartition (all-to-all) is an independent collective that a latency-
# hiding scheduler can fly while the next chunk's local FFTs compute.
# Every op in the distributed pipelines (FFTs over spatial/time dims,
# truncate/pad slices, all-to-alls) treats the channel dim as a pure batch
# dim, so running the WHOLE pipeline per channel-slice and concatenating
# is bit-identical to the unchunked call — verified by the bit-identity
# check in tests/distributed_checks.py.
# ---------------------------------------------------------------------------

def _chunk_channels(fn, x: jax.Array, chunks: int) -> jax.Array:
    n = min(int(chunks), x.shape[CDIM])
    if n <= 1:
        return fn(x)
    c = x.shape[CDIM]
    bounds = [round(i * c / n) for i in range(n + 1)]
    parts = [
        fn(jax.lax.slice_in_dim(x, lo, hi, axis=CDIM))
        for lo, hi in zip(bounds, bounds[1:])
    ]
    return jnp.concatenate(parts, axis=CDIM)


# ---------------------------------------------------------------------------
# Distributed path (call inside shard_map; x sharded along XDIM).
# ---------------------------------------------------------------------------

def dist_forward(
    x: jax.Array,
    modes: Sequence[int],
    axis_name: str,
    *,
    trunc_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Paper Alg. 2 forward transform: S_x F_x R_{x->y} S_{yzt} F_{yzt}.

    In: local real [b, c, nx/P, ny, nz, nt].
    Out: local complex [b, c, 2mx, 2my/P, 2mz, mt]
    (``trunc_x=False`` skips the final S_x — the fused Pallas kernel does
    it — leaving the x dim at full size nx).

    Truncation along y/z/t happens BEFORE the repartition — this is the
    paper's communication optimization (~160x less data on the wire than
    re-partitioning the full spectrum as in Grady et al. [31]).

    ``comm_chunks > 1`` runs the pipeline per channel-slice (bit-identical;
    see ``_chunk_channels``) so each slice's all-to-all overlaps the next
    slice's FFTs under a latency-hiding schedule.
    """
    mx, my, mz, mt = modes

    def body(x):
        # F_{yzt}: local FFT over unsharded dims (rFFT on t).
        xf = jnp.fft.rfft(x.astype(jnp.float32), axis=TDIM)
        xf = jnp.fft.fft(xf, axis=YDIM)
        xf = jnp.fft.fft(xf, axis=ZDIM)
        # S_{yzt}
        xf = truncate_full(xf, YDIM, my)
        xf = truncate_full(xf, ZDIM, mz)
        xf = truncate_rfft(xf, TDIM, mt)
        # R_{x->y}
        xf = repartition(xf, src=XDIM, dst=YDIM, axis_name=axis_name)
        # F_x, S_x
        xf = jnp.fft.fft(xf, axis=XDIM)
        if trunc_x:
            xf = truncate_full(xf, XDIM, mx)
        return xf

    return _chunk_channels(body, x, comm_chunks)


def dist_adjoint(
    xf: jax.Array,
    grid: Sequence[int],
    axis_name: str,
    out_dtype=jnp.float32,
    *,
    pad_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Paper Alg. 2 inverse: F_{yzt}^T S_{yzt}^T R^T F_x^T S_x^T.

    In: local complex [b, c, 2mx, 2my/P, 2mz, mt] (or x already full-size
    when ``pad_x=False`` — the fused kernel zero-filled S_x^T in-kernel).
    Out: local real [b, c, nx/P, ny, nz, nt].
    """
    nx, ny, nz, nt = grid

    def body(xf):
        # S_x^T, F_x^T
        if pad_x:
            xf_ = pad_full(xf, XDIM, nx)
        else:
            xf_ = xf
        xf_ = jnp.fft.ifft(xf_, axis=XDIM)
        # R_{x->y}^T = R_{y->x}
        xf_ = repartition(xf_, src=YDIM, dst=XDIM, axis_name=axis_name)
        # S_{yzt}^T, F_{yzt}^T
        xf_ = pad_full(xf_, YDIM, ny)
        xf_ = pad_full(xf_, ZDIM, nz)
        xf_ = pad_rfft(xf_, TDIM, nt // 2 + 1)
        xf_ = jnp.fft.ifft(xf_, axis=YDIM)
        xf_ = jnp.fft.ifft(xf_, axis=ZDIM)
        y = jnp.fft.irfft(xf_, n=nt, axis=TDIM)
        return y.astype(out_dtype)

    return _chunk_channels(body, xf, comm_chunks)


# ---------------------------------------------------------------------------
# BEYOND-PAPER schedule ("eager truncation"): truncate each dim immediately
# after ITS OWN FFT, so later FFTs run on already-truncated tensors.
# Truncation along dim a commutes exactly with an FFT along dim b != a, so
# this is bit-equivalent to the paper's Alg. 2 while cutting FFT flops by
# ~2.4x and the largest spectral intermediate by ~4x (see EXPERIMENTS §Perf).
# Communication is identical (the repartition already moved the truncated
# tensor in Alg. 2).
# ---------------------------------------------------------------------------

def dist_forward_eager(
    x: jax.Array,
    modes: Sequence[int],
    axis_name: str,
    *,
    trunc_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Like dist_forward, with per-dim eager truncation."""
    mx, my, mz, mt = modes

    def body(x):
        xf = jnp.fft.rfft(x.astype(jnp.float32), axis=TDIM)
        xf = truncate_rfft(xf, TDIM, mt)        # 33 -> mt bins before z/y FFTs
        xf = jnp.fft.fft(xf, axis=ZDIM)
        xf = truncate_full(xf, ZDIM, mz)
        xf = jnp.fft.fft(xf, axis=YDIM)
        xf = truncate_full(xf, YDIM, my)
        xf = repartition(xf, src=XDIM, dst=YDIM, axis_name=axis_name)
        xf = jnp.fft.fft(xf, axis=XDIM)
        if trunc_x:
            xf = truncate_full(xf, XDIM, mx)
        return xf

    return _chunk_channels(body, x, comm_chunks)


def dist_adjoint_eager(
    xf: jax.Array,
    grid: Sequence[int],
    axis_name: str,
    out_dtype=jnp.float32,
    *,
    pad_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Adjoint of the eager schedule: inverse FFTs run while the OTHER dims
    are still truncated; each pad happens right before its own iFFT."""
    nx, ny, nz, nt = grid

    def body(xf):
        xf_ = pad_full(xf, XDIM, nx) if pad_x else xf
        xf_ = jnp.fft.ifft(xf_, axis=XDIM)
        xf_ = repartition(xf_, src=YDIM, dst=XDIM, axis_name=axis_name)
        xf_ = pad_full(xf_, YDIM, ny)
        xf_ = jnp.fft.ifft(xf_, axis=YDIM)
        xf_ = pad_full(xf_, ZDIM, nz)
        xf_ = jnp.fft.ifft(xf_, axis=ZDIM)
        xf_ = pad_rfft(xf_, TDIM, nt // 2 + 1)
        y = jnp.fft.irfft(xf_, n=nt, axis=TDIM)
        return y.astype(out_dtype)

    return _chunk_channels(body, xf, comm_chunks)


# ---------------------------------------------------------------------------
# 2-D pencil decomposition (BEYOND-PAPER): input sharded along BOTH x and y
# on a ("mx", "my") mesh. Algorithm 2 shards a single spatial dim, capping
# model parallelism at nx/2mx devices; pencil decomposition lifts that cap
# to (nx/2mx)*(ny/2my) by composing two per-mesh-axis repartitions:
#
#   forward:  S_x F_x R^{mx}_{x->y} S_y F_y R^{my}_{y->z} S_{zt} F_{zt}
#   adjoint:  F_{zt}^T S_{zt}^T R^{my}_{z->y} F_y^T S_y^T R^{mx}_{y->x} F_x^T S_x^T
#
# Each all-to-all moves an already-truncated tensor (the paper's comm
# optimization, applied per mesh axis). Local layout through the forward:
#
#   [b,c, nx/Px, ny/Py, nz,     nt ]   rFFT t, FFT z, truncate z/t
#   [b,c, nx/Px, ny/Py, 2mz,    mt ]   R^{my}: y-shard moves to z
#   [b,c, nx/Px, ny,    2mz/Py, mt ]   FFT y, truncate y
#   [b,c, nx/Px, 2my,   2mz/Py, mt ]   R^{mx}: x-shard moves to y
#   [b,c, nx,    2my/Px,2mz/Py, mt ]   FFT x, truncate x
#   [b,c, 2mx,   2my/Px,2mz/Py, mt ]   spectral weights sharded k_y x k_z
#
# Divisibility: Px | nx, Px | 2my, Py | ny, Py | 2mz.
# ---------------------------------------------------------------------------

def dist_forward_2d(
    x: jax.Array,
    modes: Sequence[int],
    axis_names: Tuple[str, str] = ("mx", "my"),
    *,
    trunc_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Pencil-decomposed forward transform (call inside shard_map).

    In: local real [b, c, nx/Px, ny/Py, nz, nt], sharded x on
    ``axis_names[0]`` and y on ``axis_names[1]``.
    Out: local complex [b, c, 2mx, 2my/Px, 2mz/Py, mt].
    """
    ax_x, ax_y = axis_names
    mx, my, mz, mt = modes

    def body(x):
        # F_{zt}, S_{zt}: both dims are unsharded on every pencil.
        xf = jnp.fft.rfft(x.astype(jnp.float32), axis=TDIM)
        xf = jnp.fft.fft(xf, axis=ZDIM)
        xf = truncate_full(xf, ZDIM, mz)
        xf = truncate_rfft(xf, TDIM, mt)
        # R^{my}_{y->z}: unshard y by sharding the (truncated) z dim.
        xf = repartition(xf, src=YDIM, dst=ZDIM, axis_name=ax_y)
        xf = jnp.fft.fft(xf, axis=YDIM)
        xf = truncate_full(xf, YDIM, my)
        # R^{mx}_{x->y}: unshard x by sharding the (truncated) y dim.
        xf = repartition(xf, src=XDIM, dst=YDIM, axis_name=ax_x)
        xf = jnp.fft.fft(xf, axis=XDIM)
        if trunc_x:
            xf = truncate_full(xf, XDIM, mx)
        return xf

    return _chunk_channels(body, x, comm_chunks)


def dist_adjoint_2d(
    xf: jax.Array,
    grid: Sequence[int],
    axis_names: Tuple[str, str] = ("mx", "my"),
    out_dtype=jnp.float32,
    *,
    pad_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Adjoint of ``dist_forward_2d`` (each R^T is the reverse all-to-all).

    In: local complex [b, c, 2mx, 2my/Px, 2mz/Py, mt].
    Out: local real [b, c, nx/Px, ny/Py, nz, nt].
    """
    ax_x, ax_y = axis_names
    nx, ny, nz, nt = grid

    def body(xf):
        xf_ = pad_full(xf, XDIM, nx) if pad_x else xf
        xf_ = jnp.fft.ifft(xf_, axis=XDIM)
        xf_ = repartition(xf_, src=YDIM, dst=XDIM, axis_name=ax_x)
        xf_ = pad_full(xf_, YDIM, ny)
        xf_ = jnp.fft.ifft(xf_, axis=YDIM)
        xf_ = repartition(xf_, src=ZDIM, dst=YDIM, axis_name=ax_y)
        xf_ = pad_full(xf_, ZDIM, nz)
        xf_ = pad_rfft(xf_, TDIM, nt // 2 + 1)
        xf_ = jnp.fft.ifft(xf_, axis=ZDIM)
        y = jnp.fft.irfft(xf_, n=nt, axis=TDIM)
        return y.astype(out_dtype)

    return _chunk_channels(body, xf, comm_chunks)


def dist_forward_2d_eager(
    x: jax.Array,
    modes: Sequence[int],
    axis_names: Tuple[str, str] = ("mx", "my"),
    *,
    trunc_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """2-D pencil forward with per-dim eager truncation: t is truncated
    before the z FFT, so the z FFT runs on an mt-deep tensor (same flop
    saving as the 1-D eager schedule; bit-equivalent to dist_forward_2d)."""
    ax_x, ax_y = axis_names
    mx, my, mz, mt = modes

    def body(x):
        xf = jnp.fft.rfft(x.astype(jnp.float32), axis=TDIM)
        xf = truncate_rfft(xf, TDIM, mt)
        xf = jnp.fft.fft(xf, axis=ZDIM)
        xf = truncate_full(xf, ZDIM, mz)
        xf = repartition(xf, src=YDIM, dst=ZDIM, axis_name=ax_y)
        xf = jnp.fft.fft(xf, axis=YDIM)
        xf = truncate_full(xf, YDIM, my)
        xf = repartition(xf, src=XDIM, dst=YDIM, axis_name=ax_x)
        xf = jnp.fft.fft(xf, axis=XDIM)
        if trunc_x:
            xf = truncate_full(xf, XDIM, mx)
        return xf

    return _chunk_channels(body, x, comm_chunks)


def dist_adjoint_2d_eager(
    xf: jax.Array,
    grid: Sequence[int],
    axis_names: Tuple[str, str] = ("mx", "my"),
    out_dtype=jnp.float32,
    *,
    pad_x: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """Adjoint of the eager 2-D schedule: each pad happens right before its
    own iFFT, so earlier iFFTs run on still-truncated tensors."""
    ax_x, ax_y = axis_names
    nx, ny, nz, nt = grid

    def body(xf):
        xf_ = pad_full(xf, XDIM, nx) if pad_x else xf
        xf_ = jnp.fft.ifft(xf_, axis=XDIM)
        xf_ = repartition(xf_, src=YDIM, dst=XDIM, axis_name=ax_x)
        xf_ = pad_full(xf_, YDIM, ny)
        xf_ = jnp.fft.ifft(xf_, axis=YDIM)
        xf_ = repartition(xf_, src=ZDIM, dst=YDIM, axis_name=ax_y)
        xf_ = pad_full(xf_, ZDIM, nz)
        xf_ = jnp.fft.ifft(xf_, axis=ZDIM)
        xf_ = pad_rfft(xf_, TDIM, nt // 2 + 1)
        y = jnp.fft.irfft(xf_, n=nt, axis=TDIM)
        return y.astype(out_dtype)

    return _chunk_channels(body, xf, comm_chunks)


# ---------------------------------------------------------------------------
# Grady et al. [31] baseline schedule: repartition FIRST, truncate AFTER.
# Communicates the full (untruncated along y/z/t) spectrum — the paper's
# comparison point for the 160x communication reduction.
# ---------------------------------------------------------------------------

def dist_forward_untruncated(
    x: jax.Array,
    modes: Sequence[int],
    axis_name: str,
    *,
    trunc_xzt: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """[31]-style forward: F_{yzt}, R_{x->y} (full tensor!), F_x, then S.

    ``trunc_xzt=False`` leaves x/z/t untruncated for the fused Pallas
    kernel; the sharded y dim is still truncated here (truncate_y_local
    needs the collective, and truncation along y commutes with the
    later in-kernel x/z/t truncation).
    """
    mx, my, mz, mt = modes

    def body(x):
        xf = jnp.fft.rfft(x.astype(jnp.float32), axis=TDIM)
        xf = jnp.fft.fft(xf, axis=YDIM)
        xf = jnp.fft.fft(xf, axis=ZDIM)
        xf = repartition(xf, src=XDIM, dst=YDIM, axis_name=axis_name)
        xf = jnp.fft.fft(xf, axis=XDIM)
        # Truncate only now (after communication).
        if trunc_xzt:
            xf = truncate_full(xf, XDIM, mx)   # before the y gather: less data
            xf = truncate_y_local(xf, my, axis_name)
            xf = truncate_full(xf, ZDIM, mz)
            xf = truncate_rfft(xf, TDIM, mt)
        else:
            xf = truncate_y_local(xf, my, axis_name)
        return xf

    return _chunk_channels(body, x, comm_chunks)


def truncate_y_local(xf: jax.Array, my: int, axis_name: str) -> jax.Array:
    """Truncate the (sharded) y dim to its local slice of the kept modes.

    With y sharded P-ways, the kept modes [:my] + [-my:] live on the first
    and last shards. Each shard materializes the full kept-y range via
    an all_gather then slices its local part — simple and only used by the
    [31] baseline path (which is deliberately communication-heavy).
    """
    full = jax.lax.all_gather(xf, axis_name, axis=YDIM, tiled=True)
    kept = truncate_full(full, YDIM, my)
    p = compat.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    local = kept.shape[YDIM] // p
    return jax.lax.dynamic_slice_in_dim(kept, idx * local, local, axis=YDIM)


def pad_y_local(xf: jax.Array, ny: int, axis_name: str) -> jax.Array:
    """Adjoint-ish inverse of truncate_y_local for the [31] baseline path."""
    full_kept = jax.lax.all_gather(xf, axis_name, axis=YDIM, tiled=True)
    padded = pad_full(full_kept, YDIM, ny)
    p = compat.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    local = ny // p
    return jax.lax.dynamic_slice_in_dim(padded, idx * local, local, axis=YDIM)


def dist_adjoint_untruncated(
    xf: jax.Array,
    grid: Sequence[int],
    axis_name: str,
    out_dtype=jnp.float32,
    *,
    pad_xzt: bool = True,
    comm_chunks: int = 1,
) -> jax.Array:
    """[31]-style inverse: pad everything first, repartition the FULL tensor.

    ``pad_xzt=False`` means x/z/t arrive already full-size (the fused
    kernel zero-filled them); only the sharded y dim still needs its
    collective pad.
    """
    nx, ny, nz, nt = grid

    def body(xf):
        if pad_xzt:
            xf_ = pad_full(xf, XDIM, nx)
            xf_ = pad_y_local(xf_, ny, axis_name)
            xf_ = pad_full(xf_, ZDIM, nz)
            xf_ = pad_rfft(xf_, TDIM, nt // 2 + 1)
        else:
            xf_ = pad_y_local(xf, ny, axis_name)
        xf_ = jnp.fft.ifft(xf_, axis=XDIM)
        xf_ = repartition(xf_, src=YDIM, dst=XDIM, axis_name=axis_name)
        xf_ = jnp.fft.ifft(xf_, axis=YDIM)
        xf_ = jnp.fft.ifft(xf_, axis=ZDIM)
        y = jnp.fft.irfft(xf_, n=nt, axis=TDIM)
        return y.astype(out_dtype)

    return _chunk_channels(body, xf, comm_chunks)
