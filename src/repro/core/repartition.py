"""The paper's re-partition operator R_{x->y} as a JAX collective.

DistDL's ``repartition`` generalizes all-to-all to arbitrary Cartesian
tensors: move the sharded dimension of a tensor from dim ``src`` to dim
``dst``. Inside ``shard_map`` this is exactly ``jax.lax.all_to_all`` with
``split_axis=dst, concat_axis=src, tiled=True``:

  local X: [..., n_src/P (dim src), ..., n_dst (dim dst), ...]
  after : [..., n_src   (dim src), ..., n_dst/P (dim dst), ...]

The adjoint (conjugate transpose) of R_{src->dst} is R_{dst->src} — all-to-all
is a permutation of elements across devices, so its transpose is its inverse.
This property is exercised by the round-trip and dot-product tests.

This primitive is used by (a) the distributed FNO block (Alg. 2), (b) the
Ulysses-style sequence-parallel attention, and (c) MoE expert dispatch —
i.e. the paper's core communication pattern is a single reusable op here.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def repartition(x: jax.Array, src: int, dst: int, axis_name: str) -> jax.Array:
    """Move the sharded dim from ``src`` to ``dst`` (call inside shard_map).

    ``x`` is the *local* shard: dim ``src`` holds the local chunk (global
    size / P) and dim ``dst`` is fully local. After the call, dim ``src`` is
    global and dim ``dst`` holds the local chunk.
    """
    if src == dst:
        raise ValueError("src and dst dims must differ")
    return jax.lax.all_to_all(
        x, axis_name, split_axis=dst, concat_axis=src, tiled=True
    )


def repartition_t(x: jax.Array, src: int, dst: int, axis_name: str) -> jax.Array:
    """Adjoint of ``repartition(., src, dst)`` = ``repartition(., dst, src)``."""
    return repartition(x, dst, src, axis_name)


def repartition_chunked(
    x: jax.Array,
    src: int,
    dst: int,
    axis_name: str,
    *,
    chunks: int = 2,
    chunk_dim: int = 1,
) -> jax.Array:
    """Double-buffered ``repartition``: split along ``chunk_dim`` (default
    the channel dim of the canonical [b,c,x,y,z,t] layout), issue one
    all-to-all per chunk, concatenate.

    Bit-identical to the blocking call — all-to-all is a pure element
    permutation that never mixes values across ``chunk_dim``, so slicing
    first and permuting per-slice lands every element at the same place
    with the same value. What changes is the schedule: the per-chunk
    collectives are independent of each other, so a latency-hiding
    scheduler (see ``launch.devices.OVERLAP_XLA_FLAGS``) can fly chunk
    i's wire transfer while chunk i+1's producer (the local FFT work
    feeding this repartition) is still computing — the MPI-overlap
    recipe of Totounferoush et al., expressed at the XLA level.

    ``chunks`` is clamped to the ``chunk_dim`` extent; chunk sizes may be
    uneven (no divisibility requirement).
    """
    if chunk_dim in (src, dst):
        raise ValueError(
            f"chunk_dim {chunk_dim} must differ from src={src}/dst={dst}"
        )
    n = min(int(chunks), x.shape[chunk_dim])
    if n <= 1:
        return repartition(x, src, dst, axis_name)
    c = x.shape[chunk_dim]
    bounds = [round(i * c / n) for i in range(n + 1)]
    parts = [
        repartition(
            jax.lax.slice_in_dim(x, lo, hi, axis=chunk_dim),
            src, dst, axis_name,
        )
        for lo, hi in zip(bounds, bounds[1:])
    ]
    return jnp.concatenate(parts, axis=chunk_dim)


Move = Tuple[int, int, str]  # (src_dim, dst_dim, mesh_axis_name)


def repartition_multi(x: jax.Array, moves: Sequence[Move]) -> jax.Array:
    """Apply a sequence of per-mesh-axis moves back-to-back.

    Each move (src, dst, axis) is an independent all-to-all over ONE named
    mesh axis; the sharding of dims held by other mesh axes is untouched.
    Note the pencil FFT in ``repro.core.dfft`` does NOT call this helper —
    its two moves are interleaved with FFT/truncation steps — but performs
    the equivalent per-axis ``repartition`` calls inline; this helper is for
    schedules that re-partition several axes with no compute in between
    (e.g. transposing a whole pencil layout in one shot).
    """
    for src, dst, axis_name in moves:
        x = repartition(x, src, dst, axis_name)
    return x


def repartition_multi_t(x: jax.Array, moves: Sequence[Move]) -> jax.Array:
    """Adjoint of ``repartition_multi``: reversed moves, each transposed."""
    for src, dst, axis_name in reversed(moves):
        x = repartition(x, dst, src, axis_name)
    return x
