"""GPipe-style pipeline-parallel FNO — the paper's comparison baseline.

The paper (Fig. 6/7) shows pipeline parallelism reaches <=50% parallel
efficiency on the FNO (no concurrency at batch size 1, bubble-bound at small
microbatch counts) while domain decomposition exceeds 90%. To reproduce that
comparison we implement an honest GPipe schedule in JAX:

  * the n_blocks FNO blocks are the pipeline stages, one per device on the
    ``model`` axis (block params sharded on their leading stacked dim);
  * the batch is split into M microbatches; a shard_map loop advances the
    pipeline with ``jax.lax.ppermute`` (stage i -> i+1) each tick;
  * encoder/decoder (cheap 1x1 convs) run replicated outside the pipe;
  * bubble fraction = (P-1)/(M+P-1), which is the quantity the paper's
    Fig. 6 measures indirectly (50% efficiency at P=2, M=1, etc.).

Backward works through ``jax.grad`` (ppermute transposes to the reverse
permutation), so train-step comparisons DD-vs-PP are possible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common import compat
from repro.core import fno as fno_lib
from repro.core.fno import FNOConfig


def _pipeline_blocks(blocks, h_micro, cfg: FNOConfig, axis_name: str):
    """Run microbatches through the block pipeline. Call inside shard_map.

    blocks: this stage's block params (leading n_blocks dim already sharded
      to size 1 by shard_map) — squeezed inside.
    h_micro: [M, mb, width, nx, ny, nz, nt] replicated microbatch stack.
    Returns the same stack after all blocks, replicated via psum.
    """
    p = compat.axis_size(axis_name)
    stage = jax.lax.axis_index(axis_name)
    m = h_micro.shape[0]
    w_spec = blocks["w_spec"][0]
    w_b = blocks["w_bypass"][0]
    b_b = blocks["b_bypass"][0]

    perm = [(i, i + 1) for i in range(p - 1)]
    n_ticks = m + p - 1
    zeros = jnp.zeros_like(h_micro[0])

    def tick(carry, t):
        recv, outs = carry
        inp = jnp.where(t < m, h_micro[jnp.minimum(t, m - 1)], zeros)
        h_in = jnp.where(stage == 0, inp, recv)
        y = fno_lib.fno_block(h_in, w_spec, w_b, b_b, cfg)
        recv = jax.lax.ppermute(y, axis_name, perm)
        # Last stage emits microbatch t-(p-1) at tick t.
        out_idx = t - (p - 1)
        is_out = jnp.logical_and(stage == p - 1, out_idx >= 0)
        outs = jax.lax.cond(
            out_idx >= 0,
            lambda o: o.at[jnp.maximum(out_idx, 0)].set(
                jnp.where(is_out, y, o[jnp.maximum(out_idx, 0)])
            ),
            lambda o: o,
            outs,
        )
        return (recv, outs), None

    outs0 = jnp.zeros_like(h_micro)
    (_, outs), _ = jax.lax.scan(tick, (zeros, outs0), jnp.arange(n_ticks))
    # Only the last stage holds real outputs; broadcast to all stages.
    outs = jnp.where(stage == p - 1, outs, jnp.zeros_like(outs))
    return jax.lax.psum(outs, axis_name)


def make_pipeline_forward(
    mesh: Mesh,
    cfg: FNOConfig,
    *,
    n_micro: int,
    model_axis: str = "model",
):
    """Build jit-able pipeline forward: (params, x[b,...]) -> y[b,...].

    Requires cfg.n_blocks == mesh size along the model axis and
    batch % n_micro == 0.
    """
    p = mesh.shape[model_axis]
    if cfg.n_blocks != p:
        raise ValueError(
            f"pipeline needs n_blocks == stages ({cfg.n_blocks} != {p})"
        )

    block_specs = {
        "w_spec": P(model_axis, None, None, None, None, None, None),
        "w_bypass": P(model_axis, None, None),
        "b_bypass": P(model_axis, None),
    }

    def fwd(params, x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        h = fno_lib._encoder(params, x, cfg)
        h_micro = h.reshape((n_micro, b // n_micro) + h.shape[1:])

        piped = compat.shard_map(
            lambda blocks, hm: _pipeline_blocks(blocks, hm, cfg, model_axis),
            mesh,
            (block_specs, P()),
            P(),
        )(params["blocks"], h_micro)

        h = piped.reshape((b,) + piped.shape[2:])
        return fno_lib._decoder(params, h, cfg)

    return fwd


def bubble_efficiency(p: int, n_micro: int) -> float:
    """Ideal GPipe parallel efficiency: M / (M + P - 1)."""
    return n_micro / (n_micro + p - 1)
