"""Sequence-parallel attention via the paper's repartition primitive.

Beyond-paper application of the core idea: the FNO block re-partitions the
sharded *spatial* dim to make the FFT local; attention needs the *sequence*
dim local per head. The identical all-to-all pattern (DeepSpeed-Ulysses)
gives sequence parallelism for the LM architectures:

    q,k,v [b, s/P, h, d]  --R_{s->h}-->  [b, s, h/P, d]
    local attention over full sequence for h/P heads
    o     [b, s, h/P, d]  --R_{h->s}-->  [b, s/P, h, d]

GQA: if kv_heads is divisible by P the same repartition applies to k/v;
otherwise k/v are all-gathered along the sequence axis (cheap when
kv_heads << heads, e.g. MQA).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common import compat
from repro.core.repartition import repartition


def ulysses_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    axis_name: str,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    attn_fn=None,
) -> jax.Array:
    """Call inside shard_map; q/k/v are local shards [b, s/P, h(kv), d].

    attn_fn(q, k, v, causal, scale) computes local attention with layout
    [b, s, h, d]; defaults to a dense reference. Returns [b, s/P, h, d].
    """
    p = compat.axis_size(axis_name)
    h = q.shape[2]
    kvh = k.shape[2]
    if h % p:
        raise ValueError(f"heads {h} not divisible by axis size {p}")

    # R_{s->h}: seq-sharded -> head-sharded.
    hp = h // p
    q = repartition(q, src=1, dst=2, axis_name=axis_name)
    if kvh % p == 0:
        k = repartition(k, src=1, dst=2, axis_name=axis_name)
        v = repartition(v, src=1, dst=2, axis_name=axis_name)
    else:
        # few kv heads (GQA/MQA): gather the sequence, then select the kv
        # head(s) that serve this shard's q heads
        k = jax.lax.all_gather(k, axis_name, axis=1, tiled=True)
        v = jax.lax.all_gather(v, axis_name, axis=1, tiled=True)
        group = h // kvh
        local_q_heads = jax.lax.axis_index(axis_name) * hp + jnp.arange(hp)
        kv_idx = local_q_heads // group
        k = jnp.take(k, kv_idx, axis=2)
        v = jnp.take(v, kv_idx, axis=2)

    if attn_fn is None:
        attn_fn = _dense_attention
    o = attn_fn(q, k, v, causal=causal, scale=scale)

    # R_{h->s}: back to sequence-sharded.
    return repartition(o, src=2, dst=1, axis_name=axis_name)


def _dense_attention(q, k, v, *, causal, scale):
    b, s, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:  # GQA: repeat kv heads per group
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if causal:
        sk = k.shape[1]
        mask = jnp.tril(jnp.ones((s, sk), bool), k=sk - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)
