"""Cartesian partition descriptors (DistDL-style) for JAX meshes.

The paper's model parallelism is expressed over Cartesian partitions of
high-dimensional tensors ("the input tensor X_{bcxyzt} is distributed across
the first spatial dimension x"). In JAX the partition is a mapping from
tensor dims to named mesh axes; this module gives that mapping a first-class
descriptor with validation (divisibility) and conversion to PartitionSpec /
NamedSharding, so the FNO core and the tests share one source of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import compat

AxisName = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class CartPartition:
    """Maps tensor dimensions to mesh axis names.

    ``dims[i]`` is the mesh axis (or tuple of axes) sharding tensor dim i,
    or None for a replicated dim. This is a thin, validated wrapper around
    PartitionSpec that also remembers *which* dim is "the partitioned dim"
    for the paper's repartition operator.
    """

    dims: Tuple[Optional[AxisName], ...]

    def spec(self) -> P:
        return P(*self.dims)

    def sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec())

    def sharded_dims(self) -> Tuple[int, ...]:
        return tuple(i for i, a in enumerate(self.dims) if a is not None)

    def axis_of(self, dim: int) -> Optional[AxisName]:
        return self.dims[dim]

    def with_moved(
        self, src_dim: int, dst_dim: int, axis: Optional[str] = None
    ) -> "CartPartition":
        """Partition after repartitioning src_dim -> dst_dim (R_{x->y}).

        ``axis`` selects WHICH mesh axis moves when src_dim is sharded by
        several (pencil decomposition); omitted, the dim must be sharded by
        exactly one axis and that axis moves. If dst_dim is already sharded,
        the moved axis is appended to its axis tuple (innermost position),
        so chained per-mesh-axis moves compose.
        """
        src_axes = self.dims[src_dim]
        if src_axes is None:
            raise ValueError(f"dim {src_dim} is not sharded; cannot repartition")
        src_tuple = (src_axes,) if isinstance(src_axes, str) else tuple(src_axes)
        if axis is None:
            if len(src_tuple) != 1:
                raise ValueError(
                    f"dim {src_dim} sharded by multiple axes {src_tuple}; "
                    "name the axis to move"
                )
            axis = src_tuple[0]
        if axis not in src_tuple:
            raise ValueError(f"dim {src_dim} not sharded by axis {axis!r}")
        remaining = tuple(a for a in src_tuple if a != axis)
        dst_axes = self.dims[dst_dim]
        dst_tuple = (
            () if dst_axes is None
            else (dst_axes,) if isinstance(dst_axes, str)
            else tuple(dst_axes)
        )
        if axis in dst_tuple:
            raise ValueError(f"dim {dst_dim} already sharded by {axis!r}")
        new_dst = dst_tuple + (axis,)

        def _pack(axes: Tuple[str, ...]) -> Optional[AxisName]:
            if not axes:
                return None
            return axes[0] if len(axes) == 1 else axes

        new = list(self.dims)
        new[src_dim] = _pack(remaining)
        new[dst_dim] = _pack(new_dst)
        return CartPartition(tuple(new))

    def validate(self, shape: Sequence[int], mesh: Mesh) -> None:
        """Check every sharded dim is divisible by its mesh-axis size."""
        for i, axis in enumerate(self.dims):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if shape[i] % size != 0:
                raise ValueError(
                    f"tensor dim {i} (size {shape[i]}) not divisible by mesh "
                    f"axes {axes} (product {size})"
                )


def axis_size(mesh_or_none, axis: str) -> int:
    """Size of a named axis, from a Mesh or from inside shard_map."""
    if mesh_or_none is None:
        return compat.axis_size(axis)
    return mesh_or_none.shape[axis]


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Version-portable jax.make_mesh (Auto axis types where supported)."""
    return compat.make_mesh(shape, axes)
