"""Cartesian partition descriptors (DistDL-style) for JAX meshes.

The paper's model parallelism is expressed over Cartesian partitions of
high-dimensional tensors ("the input tensor X_{bcxyzt} is distributed across
the first spatial dimension x"). In JAX the partition is a mapping from
tensor dims to named mesh axes; this module gives that mapping a first-class
descriptor with validation (divisibility) and conversion to PartitionSpec /
NamedSharding, so the FNO core and the tests share one source of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class CartPartition:
    """Maps tensor dimensions to mesh axis names.

    ``dims[i]`` is the mesh axis (or tuple of axes) sharding tensor dim i,
    or None for a replicated dim. This is a thin, validated wrapper around
    PartitionSpec that also remembers *which* dim is "the partitioned dim"
    for the paper's repartition operator.
    """

    dims: Tuple[Optional[AxisName], ...]

    def spec(self) -> P:
        return P(*self.dims)

    def sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec())

    def sharded_dims(self) -> Tuple[int, ...]:
        return tuple(i for i, a in enumerate(self.dims) if a is not None)

    def axis_of(self, dim: int) -> Optional[AxisName]:
        return self.dims[dim]

    def with_moved(self, src_dim: int, dst_dim: int) -> "CartPartition":
        """Partition after repartitioning src_dim -> dst_dim (R_{x->y})."""
        axis = self.dims[src_dim]
        if axis is None:
            raise ValueError(f"dim {src_dim} is not sharded; cannot repartition")
        if self.dims[dst_dim] is not None:
            raise ValueError(f"dim {dst_dim} already sharded by {self.dims[dst_dim]}")
        new = list(self.dims)
        new[src_dim] = None
        new[dst_dim] = axis
        return CartPartition(tuple(new))

    def validate(self, shape: Sequence[int], mesh: Mesh) -> None:
        """Check every sharded dim is divisible by its mesh-axis size."""
        for i, axis in enumerate(self.dims):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if shape[i] % size != 0:
                raise ValueError(
                    f"tensor dim {i} (size {shape[i]}) not divisible by mesh "
                    f"axes {axes} (product {size})"
                )


def axis_size(mesh_or_none, axis: str) -> int:
    """Size of a named axis, from a Mesh or from inside shard_map."""
    if mesh_or_none is None:
        return jax.lax.axis_size(axis)
    return mesh_or_none.shape[axis]


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (silences 0.9 migration)."""
    return jax.make_mesh(
        tuple(shape),
        tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
