"""The paper's contribution: domain-decomposed model parallelism for FNOs.

Public surface:
  * ``CartPartition`` / ``repartition`` — DistDL-style partition + R_{x->y}
  * ``dfft`` — distributed truncated 4-D FFT (Alg. 2 operators + adjoints)
  * ``FNOConfig`` / ``fno_forward`` / ``make_dist_forward`` — serial oracle
    and model-parallel FNO (paper + [31] baseline schedules)
  * ``make_pipeline_forward`` — GPipe baseline the paper compares against
  * ``ulysses_attention`` — the repartition primitive applied to attention
"""
from repro.core.partition import CartPartition, make_mesh  # noqa: F401
from repro.core.repartition import (  # noqa: F401
    repartition,
    repartition_chunked,
    repartition_multi,
    repartition_multi_t,
    repartition_t,
)
from repro.core.fno import (  # noqa: F401
    FNOConfig,
    contrib_spec,
    deep_split_forward_and_specs,
    encoder_prelift,
    fno_forward,
    fno_forward_deep_split,
    fno_forward_dist,
    fno_forward_dist_2d,
    fno_forward_split,
    forward_and_specs,
    init_params,
    make_dist_forward,
    make_dist_forward_deep_split,
    make_dist_forward_split,
    mse_loss,
    param_specs,
    params_with_planes,
    params_without_planes,
    spectral_prelift,
    split_forward_and_specs,
)
from repro.core.pipeline import bubble_efficiency, make_pipeline_forward  # noqa: F401
from repro.core.ulysses import ulysses_attention  # noqa: F401
