"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x: [..., d]; w: [d]. f32 statistics, output in x.dtype.
    Uses the (1 + w) gemma-style convention when w is zero-initialized is
    NOT applied here — plain ``x_hat * w``; callers add 1 where needed."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)
