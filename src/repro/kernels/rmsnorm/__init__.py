from repro.kernels.rmsnorm.ops import rmsnorm  # noqa: F401
from repro.kernels.rmsnorm.ref import rmsnorm_ref  # noqa: F401
