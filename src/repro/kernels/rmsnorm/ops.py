"""Public wrapper for fused RMSNorm (leading-dim flattening + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    use_pallas: bool = False,
    block_rows: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if not use_pallas:
        return rmsnorm_ref(x, w, eps)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = rmsnorm_pallas(x2, w, eps=eps, block_rows=block_rows, interpret=interpret)
    if pad:
        y = y[:rows]
    return y.reshape(shape)
