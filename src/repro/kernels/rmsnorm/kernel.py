"""Pallas TPU kernel: fused RMSNorm over rows.

One HBM read + one write per element (XLA may split reduce+scale into two
passes for wide rows); rows are tiled (block_rows x d) into VMEM, statistics
in f32 vector lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """x: [rows, d] (wrapper flattens leading dims); w: [d]."""
    rows, d = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w.reshape(1, d))
