"""jit'd public wrapper for the spectral convolution.

Dispatches between the pure-XLA reference (used on CPU and in AOT dry-runs)
and the Pallas TPU kernel (validated in interpret mode on CPU). The wrapper
owns layout: flattening mode dims to K, splitting complex into re/im planes,
and padding K to the kernel's block size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spectral_conv.kernel import spectral_apply_pallas
from repro.kernels.spectral_conv.ref import spectral_apply_ref


def spectral_apply(
    xf: jax.Array,
    w: jax.Array,
    *,
    use_pallas: bool = False,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """xf: [b, ci, *modes] complex; w: [ci, co, *modes] complex.

    Returns [b, co, *modes] complex.
    """
    if not use_pallas:
        return spectral_apply_ref(xf, w)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    b, ci, *modes = xf.shape
    co = w.shape[1]
    k = 1
    for m in modes:
        k *= int(m)

    # [b, ci, K] -> [K, b, ci]; [ci, co, K] -> [K, ci, co]
    x2 = jnp.moveaxis(xf.reshape(b, ci, k), -1, 0)
    w2 = jnp.moveaxis(w.reshape(ci, co, k), -1, 0)

    pad = (-k) % block_k
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0), (0, 0)))
        w2 = jnp.pad(w2, ((0, pad), (0, 0), (0, 0)))

    yr, yi = spectral_apply_pallas(
        jnp.real(x2).astype(jnp.float32),
        jnp.imag(x2).astype(jnp.float32),
        jnp.real(w2).astype(jnp.float32),
        jnp.imag(w2).astype(jnp.float32),
        block_k=block_k,
        interpret=interpret,
    )
    y = yr + 1j * yi
    if pad:
        y = y[:k]
    return jnp.moveaxis(y, 0, -1).reshape(b, co, *modes)
