"""jit'd public wrappers for the spectral convolution.

Dispatches between the pure-XLA reference (used on CPU and in AOT dry-runs)
and the Pallas TPU kernels (validated in interpret mode on CPU). The
wrappers own layout and autodiff:

- ``spectral_apply``: pre-truncated modes, flattened-K kernel. The wrapper
  flattens mode dims to K, splits complex into re/im planes, and pads K to
  the kernel's block size.
- ``spectral_apply_fused``: full-spectrum input; the kernel fuses mode
  truncation, the complex channel mix, and zero-padding into one HBM pass.
- the weight-plane cache: ``cached_weight_planes(w_spec)`` computes the
  float32 (re, im) planes once per weight buffer and reuses them across
  training steps and serving rollout steps (both wrappers accept a
  ``(wr, wi)`` planes tuple in place of complex ``w``).

Autodiff: jax cannot differentiate through ``pallas_call`` in interpret
mode, so both Pallas paths carry a ``jax.custom_vjp``. The VJP follows
JAX's convention for complex bilinear ops — plain transpose, NO
conjugation — so the backward mixes have the same 4-real-matmul structure
as the forward:

  x_bar = g . w^T   (contract co):  gxr = gr.wr - gi.wi, gxi = gr.wi + gi.wr
  w_bar = x ._b g   (contract b):   gwr = xr.gr - xi.gi, gwi = xr.gi + xi.gr

which means dx literally reuses the forward kernel with transposed weight
planes, and dw is one extra kernel of the same shape family.
"""
from __future__ import annotations

import functools
import weakref

import jax
import jax.numpy as jnp

from repro.kernels.spectral_conv.kernel import (
    spectral_apply_pallas,
    spectral_dw_pallas,
    spectral_fused_dw,
    spectral_fused_pallas,
)
from repro.kernels.spectral_conv.ref import (
    pad_kept_ref,
    spectral_apply_fused_ref,
    spectral_apply_ref,
)


def _planes(z: jax.Array):
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Weight-plane layout cache.
# ---------------------------------------------------------------------------

weight_planes = _planes
weight_planes.__doc__ = (
    "Split a complex weight tensor into float32 (re, im) planes, keeping "
    "the mode dims unflattened so the planes shard with the same "
    "PartitionSpec as the complex original."
)

# buffer identity -> (weakref-or-array, planes). Host-side: call OUTSIDE
# jit (under a trace, id() is a tracer id and caching would be wrong).
_PLANE_CACHE: dict = {}
_PLANE_STATS = {"hits": 0, "misses": 0}


def cached_weight_planes(w: jax.Array):
    """Memoized ``weight_planes``: one re/im split per live weight buffer.

    Keyed on buffer identity (id + shape + dtype), validated against a
    weakref to the original array so a recycled id can never serve stale
    planes. Intended for frozen params (serving / eval): FNORunner calls
    this once per checkpoint instead of re-laying-out ``w_spec`` on every
    block of every rollout step.
    """
    key = (id(w), tuple(w.shape), str(w.dtype))
    hit = _PLANE_CACHE.get(key)
    if hit is not None:
        ref, planes = hit
        src = ref() if isinstance(ref, weakref.ref) else ref
        if src is w:
            _PLANE_STATS["hits"] += 1
            return planes
        del _PLANE_CACHE[key]
    _PLANE_STATS["misses"] += 1
    planes = weight_planes(w)
    try:
        ref = weakref.ref(w, lambda _ref: _PLANE_CACHE.pop(key, None))
    except TypeError:  # array type without weakref support: strong ref
        ref = w
    _PLANE_CACHE[key] = (ref, planes)
    return planes


def plane_cache_stats() -> dict:
    return {**_PLANE_STATS, "entries": len(_PLANE_CACHE)}


def clear_plane_cache() -> None:
    _PLANE_CACHE.clear()
    _PLANE_STATS["hits"] = 0
    _PLANE_STATS["misses"] = 0


def _as_complex(w):
    if isinstance(w, tuple):
        wr, wi = w
        return wr + 1j * wi
    return w


# ---------------------------------------------------------------------------
# Flattened-K path (modes pre-truncated upstream).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _flat_vjp(block_k: int, interpret):
    """custom_vjp'd flattened mix over complex (x2 [K,b,ci], w2 [K,ci,co]),
    K already padded to a block_k multiple."""

    def _mix(x2, w2):
        yr, yi = spectral_apply_pallas(
            *_planes(x2), *_planes(w2), block_k=block_k, interpret=interpret
        )
        return (yr + 1j * yi).astype(jnp.complex64)

    @jax.custom_vjp
    def f(x2, w2):
        return _mix(x2, w2)

    def fwd(x2, w2):
        return _mix(x2, w2), (x2, w2)

    def bwd(res, g):
        x2, w2 = res
        # dx = g . w^T (plain transpose): forward kernel, ci/co swapped.
        w2t = jnp.swapaxes(w2, 1, 2)
        gxr, gxi = spectral_apply_pallas(
            *_planes(g), *_planes(w2t), block_k=block_k, interpret=interpret
        )
        # dw = x ._b g (contract batch).
        gwr, gwi = spectral_dw_pallas(
            *_planes(x2), *_planes(g), block_k=block_k, interpret=interpret
        )
        return (
            (gxr + 1j * gxi).astype(x2.dtype),
            (gwr + 1j * gwi).astype(w2.dtype),
        )

    f.defvjp(fwd, bwd)
    return f


def spectral_apply(
    xf: jax.Array,
    w,
    *,
    use_pallas: bool = False,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """xf: [b, ci, *modes] complex; w: [ci, co, *modes] complex, or a
    ``(wr, wi)`` float planes tuple (e.g. from ``cached_weight_planes``).

    Returns [b, co, *modes] complex. Differentiable on both paths.
    """
    w = _as_complex(w)
    if not use_pallas:
        return spectral_apply_ref(xf, w)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    b, ci, *modes = xf.shape
    co = w.shape[1]
    k = 1
    for m in modes:
        k *= int(m)

    # [b, ci, K] -> [K, b, ci]; [ci, co, K] -> [K, ci, co]
    x2 = jnp.moveaxis(xf.reshape(b, ci, k), -1, 0)
    w2 = jnp.moveaxis(w.reshape(ci, co, k), -1, 0)

    pad = (-k) % block_k
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0), (0, 0)))
        w2 = jnp.pad(w2, ((0, pad), (0, 0), (0, 0)))

    y = _flat_vjp(block_k, interpret)(x2, w2)
    if pad:
        y = y[:k]
    return jnp.moveaxis(y, 0, -1).reshape(b, co, *modes)


# ---------------------------------------------------------------------------
# Fused truncate + mix + pad path (full-spectrum input).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fused_vjp(trunc, t_out, interpret):
    """custom_vjp'd fused op over complex (xf, w)."""

    def _mix(xf, w):
        yr, yi = spectral_fused_pallas(
            *_planes(xf), *_planes(w), trunc=trunc, t_out=t_out,
            interpret=interpret,
        )
        return (yr + 1j * yi).astype(jnp.complex64)

    @jax.custom_vjp
    def f(xf, w):
        return _mix(xf, w)

    def fwd(xf, w):
        return _mix(xf, w), (xf, w)

    def bwd(res, g):
        xf, w = res
        # dx = g . w^T: the forward fused kernel with ci/co-swapped planes,
        # reading the kept bins of g and padding back to xf's t extent.
        # Non-kept x positions got masked in the forward, so their
        # cotangent is the zero the pad re-inserts — exact, not approximate.
        wt = jnp.swapaxes(w, 0, 1)
        gxr, gxi = spectral_fused_pallas(
            *_planes(g), *_planes(wt), trunc=trunc, t_out=xf.shape[-1],
            interpret=interpret,
        )
        # dw = S(x) ._b S(g) on the kept grid only.
        gwr, gwi = spectral_fused_dw(
            *_planes(xf), *_planes(g), trunc=trunc,
            kept=tuple(int(s) for s in w.shape[2:]), interpret=interpret,
        )
        return (
            (gxr + 1j * gxi).astype(xf.dtype),
            (gwr + 1j * gwi).astype(w.dtype),
        )

    f.defvjp(fwd, bwd)
    return f


def spectral_apply_fused(
    xf: jax.Array,
    w,
    trunc,
    *,
    t_out: int | None = None,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused S^T (W ·) S: truncate + complex channel mix + zero-pad.

    xf: [b, ci, E1, E2, E3, T] complex spectrum. w: [ci, co, K1, K2, K3,
    KT] complex kept-mode weights, or a ``(wr, wi)`` float planes tuple.
    ``trunc[d]`` = full size N of spatial dim d (truncate/pad inside the
    kernel) or None if pre-truncated upstream. The rFFT-style trailing dim
    keeps bins [:KT] and pads back to ``t_out`` when given.

    The complex-``w`` Pallas path is differentiable (custom_vjp); the
    planes-tuple Pallas path is inference-only — it skips the complex
    re-combine entirely, which is the point of the plane cache.
    """
    trunc = tuple(trunc)
    if isinstance(w, tuple):
        wr, wi = w
        if not use_pallas:
            return spectral_apply_fused_ref(xf, wr + 1j * wi, trunc, t_out)
        yr, yi = spectral_fused_pallas(
            *_planes(xf), wr, wi, trunc=trunc, t_out=t_out,
            interpret=interpret,
        )
        return (yr + 1j * yi).astype(jnp.complex64)
    if not use_pallas:
        return spectral_apply_fused_ref(xf, w, trunc, t_out)
    return _fused_vjp(trunc, t_out, interpret)(xf, w)


# ---------------------------------------------------------------------------
# Static-contribution split: cache W . S(static) once, run the fused kernel
# on the dynamic remainder only.
# ---------------------------------------------------------------------------

def spectral_static_contribution(sf: jax.Array, w) -> jax.Array:
    """Kept-mode static contribution C = W . S(h_static).

    sf: [b, ci, K1, K2, K3, KT] (or unbatched [ci, ...]) truncated kept-mode
    spectrum of the static activation; w: complex kept-mode weights or a
    ``(wr, wi)`` planes tuple (so serving can reuse ``cached_weight_planes``).
    C is what FNORunner caches per geomodel: because FFT -> truncate -> mix
    is linear up to the first nonlinearity, C is computed once and summed
    with the dynamic remainder's kept-mode mix on every warm request.
    """
    w = _as_complex(w)
    unbatched = sf.ndim == w.ndim - 1
    if unbatched:
        sf = sf[None]
    y = spectral_apply_ref(sf, w)
    return y[0] if unbatched else y


def spectral_apply_fused_add(
    xf: jax.Array,
    w,
    add: jax.Array,
    trunc,
    *,
    t_out: int | None = None,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused truncate+mix+pad on the dynamic remainder ``xf`` plus a cached
    kept-mode static contribution ``add`` [b, co, K1, K2, K3, KT].

    Zero-padding is linear, so pad(mix(trunc(xf))) + pad(add) ==
    pad(mix(trunc(xf)) + add): the Pallas kernel runs unmodified on the
    remainder and the cached contribution is padded into the same layout
    and summed outside.
    """
    y = spectral_apply_fused(
        xf, w, trunc, t_out=t_out, use_pallas=use_pallas, interpret=interpret
    )
    return y + pad_kept_ref(add.astype(y.dtype), trunc, t_out)
