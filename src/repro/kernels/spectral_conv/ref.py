"""Pure-jnp oracle for the spectral convolution (per-mode channel mixing).

Y[b, co, K] = sum_ci X[b, ci, K] * W[ci, co, K]   (complex), where K ranges
over the kept Fourier modes (possibly multi-dimensional, flattened or not).
This is the FLOP hot spot of the paper's FNO block (Alg. 2 line 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spectral_apply_ref(xf: jax.Array, w: jax.Array) -> jax.Array:
    """xf: [b, ci, *modes] complex; w: [ci, co, *modes] complex.

    Returns [b, co, *modes] complex. Element-wise over mode dims, contracted
    over ci (paper's einsum Y_{b c_o k...} = X_{b c_i k...} W_{c_i c_o k...}).
    """
    n_modes = xf.ndim - 2
    mode_axes = "".join(chr(ord("s") + i) for i in range(n_modes))
    eq = f"bi{mode_axes},io{mode_axes}->bo{mode_axes}"
    return jnp.einsum(eq, xf, w)
