"""Pure-jnp oracle for the spectral convolution (per-mode channel mixing).

Y[b, co, K] = sum_ci X[b, ci, K] * W[ci, co, K]   (complex), where K ranges
over the kept Fourier modes (possibly multi-dimensional, flattened or not).
This is the FLOP hot spot of the paper's FNO block (Alg. 2 line 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spectral_apply_ref(xf: jax.Array, w: jax.Array) -> jax.Array:
    """xf: [b, ci, *modes] complex; w: [ci, co, *modes] complex.

    Returns [b, co, *modes] complex. Element-wise over mode dims, contracted
    over ci (paper's einsum Y_{b c_o k...} = X_{b c_i k...} W_{c_i c_o k...}).
    """
    n_modes = xf.ndim - 2
    mode_axes = "".join(chr(ord("s") + i) for i in range(n_modes))
    eq = f"bi{mode_axes},io{mode_axes}->bo{mode_axes}"
    return jnp.einsum(eq, xf, w)


# Local truncate/pad helpers: semantically identical to core.dfft's
# truncate_full/pad_full/truncate_rfft/pad_rfft, re-stated here because
# importing repro.core from the kernel package would be a circular import
# (repro.core.fno imports this package).

def _truncate_full_ref(xf: jax.Array, axis: int, m: int) -> jax.Array:
    n = xf.shape[axis]
    lo = jax.lax.slice_in_dim(xf, 0, m, axis=axis)
    hi = jax.lax.slice_in_dim(xf, n - m, n, axis=axis)
    return jnp.concatenate([lo, hi], axis=axis)


def _pad_full_ref(yf: jax.Array, axis: int, n: int) -> jax.Array:
    k = yf.shape[axis]
    m = k // 2
    lo = jax.lax.slice_in_dim(yf, 0, m, axis=axis)
    hi = jax.lax.slice_in_dim(yf, m, k, axis=axis)
    shape = list(yf.shape)
    shape[axis] = n - k
    z = jnp.zeros(shape, yf.dtype)
    return jnp.concatenate([lo, z, hi], axis=axis)


def pad_kept_ref(yk: jax.Array, trunc, t_out: int | None = None) -> jax.Array:
    """Zero-pad a kept-mode tensor [b, co, K1, K2, K3, KT] back to the fused
    output layout: full size ``trunc[d]`` on each spatial dim where trunc[d]
    is not None, and rFFT tail-pad the trailing dim to ``t_out`` when given.
    Matches the pad half of ``spectral_apply_fused_ref`` exactly.
    """
    trunc = tuple(trunc)
    kt = yk.shape[-1]
    for d, n in enumerate(trunc):
        if n is not None:
            yk = _pad_full_ref(yk, 2 + d, n)
    if t_out is not None and t_out != kt:
        shape = list(yk.shape)
        shape[-1] = t_out - kt
        yk = jnp.concatenate([yk, jnp.zeros(shape, yk.dtype)], axis=-1)
    return yk


def spectral_apply_fused_ref(
    xf: jax.Array,
    w: jax.Array,
    trunc,
    t_out: int | None = None,
) -> jax.Array:
    """Unfused XLA oracle for the fused truncate+mix+pad op.

    xf: [b, ci, E1, E2, E3, T] complex spectrum; w: [ci, co, K1, K2, K3, KT]
    complex kept-mode weights. ``trunc[d]`` (d over the three spatial dims)
    is the full size N to truncate from / pad back to, or None if the dim
    arrives pre-truncated (E_d == K_d). The trailing dim is rFFT-style:
    keep bins [:KT], pad the tail back to ``t_out`` (or stay at KT).
    """
    trunc = tuple(trunc)
    kt = w.shape[-1]
    for d, n in enumerate(trunc):
        if n is not None:
            xf = _truncate_full_ref(xf, 2 + d, w.shape[2 + d] // 2)
    if xf.shape[-1] != kt:
        xf = jax.lax.slice_in_dim(xf, 0, kt, axis=-1)
    y = spectral_apply_ref(xf, w)
    for d, n in enumerate(trunc):
        if n is not None:
            y = _pad_full_ref(y, 2 + d, n)
    if t_out is not None and t_out != kt:
        shape = list(y.shape)
        shape[-1] = t_out - kt
        y = jnp.concatenate([y, jnp.zeros(shape, y.dtype)], axis=-1)
    return y
