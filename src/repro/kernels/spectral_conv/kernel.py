"""Pallas TPU kernels: fused complex per-mode channel mixing.

Motivation (TPU adaptation of the paper's hot spot): XLA lowers a complex
einsum into four real einsums, each re-reading its operands from HBM. For
FNO-sized spectral weights (GBs — they dominate the model), the op is
HBM-bandwidth-bound, so reading X and W once and doing the four real
MXU contractions from VMEM halves the dominant W-stream traffic.

Two kernel families live here:

1. The flattened-K mixing kernel (``spectral_apply_pallas`` /
   ``spectral_dw_pallas``): modes are flattened to a leading K dim so each
   grid step owns a contiguous K-tile:

     x:   [K, B, CI]   (split into re/im float32 planes)
     w:   [K, CI, CO]
     out: [K, B, CO]

   Grid: (K // block_k,). Each step does a batched complex matmul over its
   K-tile entirely in VMEM (yr = xr@wr - xi@wi; yi = xr@wi + xi@wr).
   BlockSpec tiling keeps the per-step VMEM footprint at
   block_k * (B*CI + CI*CO + B*CO) * 4B * 2 (re+im), sized by ``block_k``
   (default 128 -> ~4.5 MB at CI=CO=64, B=2, comfortably inside 16 MB
   VMEM). K is zero-padded to a block_k multiple by the ops.py wrapper.

2. The fused truncate+mix+pad kernel (``spectral_fused_pallas`` /
   ``spectral_fused_dw_pallas``): consumes the FULL spectrum in its natural
   [b, c, x, y, z, t] layout and fuses the FNO epilogue — mode truncation
   (S), per-mode channel mix (W·), and zero-padding (S^T) — into one pass.
   The unfused XLA pipeline materializes truncate -> mix -> pad as three
   HBM round trips of the mode tensor; here the grid walks the OUTPUT
   spatial positions (block size 1 along each to-be-truncated dim, so any
   element offset is a legal block index and no divisibility constraint
   arises), the weight BlockSpec gathers the matching kept-mode plane via
   a computed index map, and non-kept rows are masked to zero in-register
   — every operand streams from HBM exactly once. The weight planes arrive
   UNFLATTENED (same [ci, co, kx, ky, kz, kt] layout as ``w_spec``), which
   is what lets the ops-level weight-plane cache reuse one layout across
   every block call and every serving step.

Interpret-mode note: each grid step costs interpreter overhead (~ms), so
keep grids small on CPU (tests use <= a few hundred steps); on TPU the
grid is a hardware loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    """Backend-sniffed interpret default: compiled on TPU, interpreter
    elsewhere (CPU/GPU have no Pallas-TPU lowering)."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# Flattened-K mixing kernels (mode dims pre-truncated and flattened to K).
# ---------------------------------------------------------------------------

def _kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    # Batched matmul over the K tile: [k,b,ci] @ [k,ci,co] -> [k,b,co].
    dn = (((2,), (1,)), ((0,), (0,)))
    rr = jax.lax.dot_general(xr, wr, dn, preferred_element_type=jnp.float32)
    ii = jax.lax.dot_general(xi, wi, dn, preferred_element_type=jnp.float32)
    ri = jax.lax.dot_general(xr, wi, dn, preferred_element_type=jnp.float32)
    ir = jax.lax.dot_general(xi, wr, dn, preferred_element_type=jnp.float32)
    yr_ref[...] = rr - ii
    yi_ref[...] = ri + ir


def _kernel_dw(xr_ref, xi_ref, gr_ref, gi_ref, wr_ref, wi_ref):
    """dW of the complex mix under JAX's plain-transpose convention:
    w_bar = x ._b g (contract batch, NO conjugation), per K row."""
    xr = xr_ref[...]
    xi = xi_ref[...]
    gr = gr_ref[...]
    gi = gi_ref[...]
    # [k,b,ci] x [k,b,co] -> [k,ci,co] (contract b, batch k).
    dn = (((1,), (1,)), ((0,), (0,)))
    rr = jax.lax.dot_general(xr, gr, dn, preferred_element_type=jnp.float32)
    ii = jax.lax.dot_general(xi, gi, dn, preferred_element_type=jnp.float32)
    ri = jax.lax.dot_general(xr, gi, dn, preferred_element_type=jnp.float32)
    ir = jax.lax.dot_general(xi, gr, dn, preferred_element_type=jnp.float32)
    wr_ref[...] = rr - ii
    wi_ref[...] = ri + ir


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def spectral_apply_pallas(
    xr: jax.Array,
    xi: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    *,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """Real/imag planes: xr/xi [K,B,CI]; wr/wi [K,CI,CO] -> yr/yi [K,B,CO].

    K must be divisible by block_k (the ops.py wrapper pads).
    ``interpret=None`` sniffs the backend (compiled on TPU, interpreter
    elsewhere) — a direct caller on TPU gets the real kernel, matching the
    ops.py wrapper's default.
    """
    interpret = _resolve_interpret(interpret)
    k, b, ci = xr.shape
    co = wr.shape[-1]
    assert k % block_k == 0, (k, block_k)
    grid = (k // block_k,)
    x_spec = pl.BlockSpec((block_k, b, ci), lambda i: (i, 0, 0))
    w_spec = pl.BlockSpec((block_k, ci, co), lambda i: (i, 0, 0))
    y_spec = pl.BlockSpec((block_k, b, co), lambda i: (i, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((k, b, co), jnp.float32),
        jax.ShapeDtypeStruct((k, b, co), jnp.float32),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def spectral_dw_pallas(
    xr: jax.Array,
    xi: jax.Array,
    gr: jax.Array,
    gi: jax.Array,
    *,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """Weight cotangent of the flattened mix: xr/xi [K,B,CI], gr/gi
    [K,B,CO] -> wr_bar/wi_bar [K,CI,CO]. Same tiling as the forward."""
    interpret = _resolve_interpret(interpret)
    k, b, ci = xr.shape
    co = gr.shape[-1]
    assert k % block_k == 0, (k, block_k)
    grid = (k // block_k,)
    x_spec = pl.BlockSpec((block_k, b, ci), lambda i: (i, 0, 0))
    g_spec = pl.BlockSpec((block_k, b, co), lambda i: (i, 0, 0))
    w_spec = pl.BlockSpec((block_k, ci, co), lambda i: (i, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((k, ci, co), jnp.float32),
        jax.ShapeDtypeStruct((k, ci, co), jnp.float32),
    ]
    return pl.pallas_call(
        _kernel_dw,
        grid=grid,
        in_specs=[x_spec, x_spec, g_spec, g_spec],
        out_specs=[w_spec, w_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, gr, gi)


# ---------------------------------------------------------------------------
# Fused truncate + mix + pad kernels (natural [b,c,x,y,z,t] layout).
#
# ``trunc`` is a 3-tuple over the (x, y, z) mode dims: entry N (an int)
# means the input dim is the FULL spectrum of size N — the kernel keeps the
# 2m lowest-|k| modes ([:m] and [N-m:], m = K_d // 2 from the weight shape)
# and zero-fills the rest of the output; entry None means the dim was
# already truncated upstream (kept extent == input extent == output
# extent). The trailing time dim is rFFT-style: the kernel always reads
# bins [0:KT] and zero-pads the output tail up to ``t_out``.
# ---------------------------------------------------------------------------

def _validate_fused(x_shape, w_shape, trunc, t_out):
    b, ci = x_shape[:2]
    if w_shape[0] != ci:
        raise ValueError(f"w ci={w_shape[0]} != x ci={ci}")
    kt = w_shape[5]
    if x_shape[5] < kt:
        raise ValueError(f"x time bins {x_shape[5]} < weight kt={kt}")
    if t_out is not None and t_out < kt:
        raise ValueError(f"t_out={t_out} < weight kt={kt}")
    for d in range(3):
        e, k, n = x_shape[2 + d], w_shape[2 + d], trunc[d]
        if n is None:
            if e != k:
                raise ValueError(
                    f"dim {d}: pre-truncated input extent {e} != kept {k}"
                )
        else:
            if e != n:
                raise ValueError(f"dim {d}: input extent {e} != full size {n}")
            if k % 2 or k < 2:
                raise ValueError(f"dim {d}: kept extent {k} must be even >= 2")
            if k > n:
                raise ValueError(f"dim {d}: kept {k} > full {n}")


def _kept_index(i, n, m, k_max):
    """Full-spectrum position -> kept-mode index ([:m] keeps identity,
    [n-m:] lands at [m:2m]); clamped for masked (non-kept) rows."""
    return jnp.clip(jnp.where(i < m, i, i - (n - 2 * m)), 0, k_max - 1)


@functools.partial(jax.jit, static_argnames=("trunc", "t_out", "interpret"))
def spectral_fused_pallas(
    xr: jax.Array,
    xi: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    *,
    trunc,
    t_out: int | None = None,
    interpret: bool | None = None,
):
    """Fused S^T · (W ·) · S: xr/xi [B,CI,E1,E2,E3,Tin] float32 planes of
    the spectrum; wr/wi [CI,CO,K1,K2,K3,KT] planes of the kept-mode
    weights (natural w_spec layout) -> yr/yi [B,CO,E1,E2,E3,t_out or KT].

    Each grid step (one output x/y/z position) streams one [B,CI,KT] input
    pencil and one [CI,CO,KT] weight plane, does the 4-real-matmul complex
    mix, masks non-kept positions to zero, and writes the padded output —
    truncate, mix and pad in a single HBM pass.
    """
    interpret = _resolve_interpret(interpret)
    trunc = tuple(trunc)
    _validate_fused(xr.shape, wr.shape, trunc, t_out)
    b, ci = xr.shape[:2]
    co = wr.shape[1]
    e1, e2, e3 = xr.shape[2:5]
    k1, k2, k3, kt = wr.shape[2:]
    tout = kt if t_out is None else int(t_out)
    ms = (k1 // 2, k2 // 2, k3 // 2)
    kept_ext = (k1, k2, k3)

    def w_index(i, j, k):
        idx = []
        for d, p in enumerate((i, j, k)):
            if trunc[d] is None:
                idx.append(p)
            else:
                idx.append(_kept_index(p, trunc[d], ms[d], kept_ext[d]))
        return (0, 0, idx[0], idx[1], idx[2], 0)

    def kern(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
        keep = jnp.bool_(True)
        for d in range(3):
            if trunc[d] is not None:
                p = pl.program_id(d)
                keep = keep & ((p < ms[d]) | (p >= trunc[d] - ms[d]))
        xr_ = xr_ref[...][:, :, 0, 0, 0, :]   # [B,CI,KT]
        xi_ = xi_ref[...][:, :, 0, 0, 0, :]
        wr_ = wr_ref[...][:, :, 0, 0, 0, :]   # [CI,CO,KT]
        wi_ = wi_ref[...][:, :, 0, 0, 0, :]
        # contract ci, batch t -> [KT,B,CO]
        dn = (((1,), (0,)), ((2,), (2,)))
        rr = jax.lax.dot_general(xr_, wr_, dn, preferred_element_type=jnp.float32)
        ii = jax.lax.dot_general(xi_, wi_, dn, preferred_element_type=jnp.float32)
        ri = jax.lax.dot_general(xr_, wi_, dn, preferred_element_type=jnp.float32)
        ir = jax.lax.dot_general(xi_, wr_, dn, preferred_element_type=jnp.float32)
        mask = jnp.where(keep, 1.0, 0.0)
        out_r = jnp.moveaxis(rr - ii, 0, -1) * mask   # [B,CO,KT]
        out_i = jnp.moveaxis(ri + ir, 0, -1) * mask
        if tout > kt:  # fused S^T along t: zero tail, never materialized
            z = jnp.zeros((b, co, tout - kt), jnp.float32)
            out_r = jnp.concatenate([out_r, z], axis=-1)
            out_i = jnp.concatenate([out_i, z], axis=-1)
        yr_ref[...] = out_r[:, :, None, None, None, :]
        yi_ref[...] = out_i[:, :, None, None, None, :]

    grid = (e1, e2, e3)
    x_spec = pl.BlockSpec((b, ci, 1, 1, 1, kt), lambda i, j, k: (0, 0, i, j, k, 0))
    w_spec = pl.BlockSpec((ci, co, 1, 1, 1, kt), w_index)
    y_spec = pl.BlockSpec((b, co, 1, 1, 1, tout), lambda i, j, k: (0, 0, i, j, k, 0))
    out_shape = [
        jax.ShapeDtypeStruct((b, co, e1, e2, e3, tout), jnp.float32),
        jax.ShapeDtypeStruct((b, co, e1, e2, e3, tout), jnp.float32),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)


def _full_index(kd, n, m):
    """Kept-mode index -> full-spectrum position (inverse of _kept_index
    restricted to kept rows): [:m] identity, [m:2m] -> [n-m:]."""
    return jnp.where(kd < m, kd, n - 2 * m + kd)


@functools.partial(jax.jit, static_argnames=("trunc", "kept", "interpret"))
def spectral_fused_dw(
    xr: jax.Array,
    xi: jax.Array,
    gr: jax.Array,
    gi: jax.Array,
    *,
    trunc,
    kept,
    interpret: bool | None = None,
):
    """Weight cotangent of the fused op: w_bar = S(x) ._b S(g) per kept
    mode (plain transpose, no conjugation).

    xr/xi [B,CI,E1,E2,E3,Tx], gr/gi [B,CO,E1,E2,E3,Tg] are the (possibly
    full) spectrum planes the forward consumed/produced; ``kept`` is the
    weight mode shape (K1,K2,K3,KT). The grid walks kept coordinates only
    — every output element is written, so no masking or padding is needed
    — and the x/g BlockSpec index maps gather the kept full-spectrum
    positions ([:m] and [N-m:] for truncated dims, identity otherwise).
    """
    interpret = _resolve_interpret(interpret)
    trunc = tuple(trunc)
    k1, k2, k3, kt = kept
    b, ci = xr.shape[:2]
    co = gr.shape[1]
    if xr.shape[5] < kt or gr.shape[5] < kt:
        raise ValueError(f"time bins {xr.shape[5]}/{gr.shape[5]} < kt={kt}")
    ms = (k1 // 2, k2 // 2, k3 // 2)

    def xg_index(i, j, k):
        idx = []
        for d, p in enumerate((i, j, k)):
            if trunc[d] is None:
                idx.append(p)
            else:
                idx.append(_full_index(p, trunc[d], ms[d]))
        return (0, 0, idx[0], idx[1], idx[2], 0)

    def kern(xr_ref, xi_ref, gr_ref, gi_ref, wr_ref, wi_ref):
        xr_ = xr_ref[...][:, :, 0, 0, 0, :]   # [B,CI,KT]
        xi_ = xi_ref[...][:, :, 0, 0, 0, :]
        gr_ = gr_ref[...][:, :, 0, 0, 0, :]   # [B,CO,KT]
        gi_ = gi_ref[...][:, :, 0, 0, 0, :]
        # contract b, batch t -> [KT,CI,CO]
        dn = (((0,), (0,)), ((2,), (2,)))
        rr = jax.lax.dot_general(xr_, gr_, dn, preferred_element_type=jnp.float32)
        ii = jax.lax.dot_general(xi_, gi_, dn, preferred_element_type=jnp.float32)
        ri = jax.lax.dot_general(xr_, gi_, dn, preferred_element_type=jnp.float32)
        ir = jax.lax.dot_general(xi_, gr_, dn, preferred_element_type=jnp.float32)
        wr_ref[...] = jnp.moveaxis(rr - ii, 0, -1)[:, :, None, None, None, :]
        wi_ref[...] = jnp.moveaxis(ri + ir, 0, -1)[:, :, None, None, None, :]

    grid = (k1, k2, k3)
    x_spec = pl.BlockSpec((b, ci, 1, 1, 1, kt), xg_index)
    g_spec = pl.BlockSpec((b, co, 1, 1, 1, kt), xg_index)
    w_spec = pl.BlockSpec((ci, co, 1, 1, 1, kt), lambda i, j, k: (0, 0, i, j, k, 0))
    out_shape = [
        jax.ShapeDtypeStruct((ci, co, k1, k2, k3, kt), jnp.float32),
        jax.ShapeDtypeStruct((ci, co, k1, k2, k3, kt), jnp.float32),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[x_spec, x_spec, g_spec, g_spec],
        out_specs=[w_spec, w_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, gr, gi)
