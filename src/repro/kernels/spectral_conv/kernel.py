"""Pallas TPU kernel: fused complex per-mode channel mixing.

Motivation (TPU adaptation of the paper's hot spot): XLA lowers a complex
einsum into four real einsums, each re-reading its operands from HBM. For
FNO-sized spectral weights (GBs — they dominate the model), the op is
HBM-bandwidth-bound, so reading X and W once and doing the four real
MXU contractions from VMEM halves the dominant W-stream traffic.

Layout: modes are flattened to a leading K dim so each grid step owns a
contiguous K-tile:

  x:   [K, B, CI]   (split into re/im float32 planes)
  w:   [K, CI, CO]
  out: [K, B, CO]

Grid: (K // block_k,). Each step does a batched complex matmul over its
K-tile entirely in VMEM:

  yr = xr @ wr - xi @ wi;   yi = xr @ wi + xi @ wr

BlockSpec tiling keeps the per-step VMEM footprint at
block_k * (B*CI + CI*CO + B*CO) * 4B * 2 (re+im), sized by ``block_k``
(default 128 -> ~4.5 MB at CI=CO=64, B=2, comfortably inside 16 MB VMEM).
Channel dims are zero-padded to multiples of 8/128 lanes by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    # Batched matmul over the K tile: [k,b,ci] @ [k,ci,co] -> [k,b,co].
    dn = (((2,), (1,)), ((0,), (0,)))
    rr = jax.lax.dot_general(xr, wr, dn, preferred_element_type=jnp.float32)
    ii = jax.lax.dot_general(xi, wi, dn, preferred_element_type=jnp.float32)
    ri = jax.lax.dot_general(xr, wi, dn, preferred_element_type=jnp.float32)
    ir = jax.lax.dot_general(xi, wr, dn, preferred_element_type=jnp.float32)
    yr_ref[...] = rr - ii
    yi_ref[...] = ri + ir


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def spectral_apply_pallas(
    xr: jax.Array,
    xi: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    *,
    block_k: int = 128,
    interpret: bool = True,
):
    """Real/imag planes: xr/xi [K,B,CI]; wr/wi [K,CI,CO] -> yr/yi [K,B,CO].

    K must be divisible by block_k (the ops.py wrapper pads).
    """
    k, b, ci = xr.shape
    co = wr.shape[-1]
    assert k % block_k == 0, (k, block_k)
    grid = (k // block_k,)
    x_spec = pl.BlockSpec((block_k, b, ci), lambda i: (i, 0, 0))
    w_spec = pl.BlockSpec((block_k, ci, co), lambda i: (i, 0, 0))
    y_spec = pl.BlockSpec((block_k, b, co), lambda i: (i, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((k, b, co), jnp.float32),
        jax.ShapeDtypeStruct((k, b, co), jnp.float32),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)
