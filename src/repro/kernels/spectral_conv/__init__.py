from repro.kernels.spectral_conv.ops import spectral_apply  # noqa: F401
from repro.kernels.spectral_conv.ref import spectral_apply_ref  # noqa: F401
