from repro.kernels.spectral_conv.ops import (  # noqa: F401
    cached_weight_planes,
    clear_plane_cache,
    plane_cache_stats,
    spectral_apply,
    spectral_apply_fused,
    weight_planes,
)
from repro.kernels.spectral_conv.ref import (  # noqa: F401
    spectral_apply_fused_ref,
    spectral_apply_ref,
)
