from repro.kernels.spectral_conv.ops import (  # noqa: F401
    cached_weight_planes,
    clear_plane_cache,
    plane_cache_stats,
    spectral_apply,
    spectral_apply_fused,
    spectral_apply_fused_add,
    spectral_static_contribution,
    weight_planes,
)
from repro.kernels.spectral_conv.ref import (  # noqa: F401
    pad_kept_ref,
    spectral_apply_fused_ref,
    spectral_apply_ref,
)
