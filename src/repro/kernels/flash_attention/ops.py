"""Public wrapper for flash attention: padding, masking, dispatch.

``use_pallas=False`` (default on CPU / in AOT dry-runs) routes to a chunked
XLA online-softmax implementation with identical math — the dry-run roofline
then reflects flash-style memory behaviour, and the TPU runtime can flip to
the Pallas kernel without changing call sites.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    use_pallas: bool = False,
    block_q: int = 128,
    block_k: int = 128,
    chunk_k: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """q: [b, h, sq, d]; k/v: [b, kvh, sk, d] -> [b, h, sq, d]."""
    if not use_pallas:
        return attention_chunked(q, k, v, causal=causal, scale=scale, chunk_k=chunk_k)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    sq, sk = q.shape[2], k.shape[2]
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v
    # The kernel masks key positions >= true_sk and keeps the causal offset
    # aligned to the TRUE lengths; padded query rows are sliced off below.
    o = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret, true_sq=sq, true_sk=sk,
    )
    return o[:, :, :sq] if pad_q else o


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    chunk_k: int = 1024,
) -> jax.Array:
    """XLA online-softmax attention: scans kv in chunks, never builds SxS.

    Used for long sequences in training/prefill (the memory-roofline fix)
    and as the dry-run stand-in for the Pallas kernel.
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    if h != kvh:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = d ** -0.5
    if sk <= chunk_k:
        return attention_ref(q, k, v, causal=causal, scale=scale)
    true_sk = sk
    if sk % chunk_k:
        pad = chunk_k - sk % chunk_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        sk = k.shape[2]
    n_chunks = sk // chunk_k
    kc = k.reshape(b, h, n_chunks, chunk_k, d)
    vc = v.reshape(b, h, n_chunks, chunk_k, d)
    qf = q.astype(jnp.float32)
    qpos = jnp.arange(sq) + (true_sk - sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kci, vci, ci = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kci.astype(jnp.float32)) * scale
        kpos = ci * chunk_k + jnp.arange(chunk_k)
        mask = kpos[None, :] < true_sk  # padded tail keys
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask, s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vci.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), jnp.arange(n_chunks)),
    )
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
