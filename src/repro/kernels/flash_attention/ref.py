"""Pure-jnp oracle: dense softmax attention with GQA and causal masking."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """q: [b, h, sq, d]; k/v: [b, kvh, sk, d] with h % kvh == 0.

    Causal convention for sq != sk: the last query attends to the last key
    (query i sees keys j with j <= i + sk - sq).
    Returns [b, h, sq, d] in q's dtype; softmax in f32.
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    if h != kvh:
        k = jnp.repeat(k, h // kvh, axis=1)
        v = jnp.repeat(v, h // kvh, axis=1)
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(q.dtype), v)
