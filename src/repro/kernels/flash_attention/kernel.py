"""Pallas TPU kernel: flash attention forward (online softmax).

TPU adaptation notes: the memory hierarchy target is HBM -> VMEM tiles of
(block_q x d) / (block_k x d); the S x S score matrix is never materialized
(the O(S^2) memory term is what blocks 32k-prefill on 16 GB v5e chips — see
EXPERIMENTS.md §Perf). The kv loop is the innermost grid dim so the MXU sees
back-to-back (block_q x d) @ (d x block_k) matmuls with running-max/sum
rescaling in f32 VMEM scratch (vs. warp-level shuffles in GPU flash
implementations — the reduction here is a vector-lane op, which Mosaic maps
onto the VPU).

Grid: (batch*heads, sq // block_q, sk // block_k), kv innermost.
GQA is handled in the BlockSpec index maps (kv head = q head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, sq: int, sk: int, block_q: int, block_k: int
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)  # [bk, d]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < sk  # tail padding mask
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + (sk - sq)
        valid = jnp.logical_and(valid, kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]  # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "block_q", "block_k", "interpret", "true_sq", "true_sk"
    ),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    true_sq: int | None = None,
    true_sk: int | None = None,
) -> jax.Array:
    """q: [b, h, sq, d]; k/v: [b, kvh, sk, d]. sq/sk padded to block multiples
    by the ops.py wrapper; ``true_sq``/``true_sk`` are the unpadded lengths —
    padded tail keys are masked to NEG_INF, padded query rows are garbage and
    sliced off by the wrapper.
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    true_sq = sq if true_sq is None else true_sq
    true_sk = sk if true_sk is None else true_sk
    assert h % kvh == 0
    group = h // kvh
    if scale is None:
        scale = d ** -0.5
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)

    grid = (b * h, sq // block_q, sk // block_k)

    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda bh, qi, ki: (bh // h, bh % h, qi, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, d), lambda bh, qi, ki: (bh // h, (bh % h) // group, ki, 0)
    )
    o_spec = q_spec

    kernel = functools.partial(
        _flash_kernel,
        scale=float(scale),
        causal=causal,
        sq=true_sq,
        sk=true_sk,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((block_q, d), jnp.float32),
            _vmem((block_q, 1), jnp.float32),
            _vmem((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
