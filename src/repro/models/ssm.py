"""Mamba-2 (SSD — state-space duality) block: chunked matmul scan + decode.

TPU adaptation: the SSD chunked algorithm is chosen over a pure recurrent
scan because its intra-chunk work is (chunk x N) x (N x chunk) matmuls —
MXU food — while the O(S) recurrence only runs over S/chunk chunk-states.
Decode keeps the O(1) recurrent state, which is why mamba2 is the arch that
makes the long_500k cell feasible.

Sharding note: projections are stored SPLIT (w_z/w_x/w_B/w_C/w_dt instead of
one fused in_proj) so each output can be column-sharded over the model axis
without slicing a sharded dim (slices of sharded dims force XLA reshards).
The depthwise conv factorizes exactly over the x/B/C split.

Shapes per Mamba-2 defaults: d_inner = expand*d_model, heads H = d_inner /
head_dim, state N = d_state, shared B/C across heads (n_groups=1).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.policy import ParallelPolicy, LOCAL


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_dim(self, d_model: int) -> int:
        return self.d_inner(d_model) + 2 * self.n_groups * self.d_state


def init_ssm_params(key, d_model: int, ssm: SSMConfig) -> dict:
    di = ssm.d_inner(d_model)
    h = ssm.n_heads(d_model)
    gn = ssm.n_groups * ssm.d_state
    ks = jax.random.split(key, 8)
    std = d_model ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (d_model, di), jnp.float32) * std,
        "w_x": jax.random.normal(ks[1], (d_model, di), jnp.float32) * std,
        "w_B": jax.random.normal(ks[2], (d_model, gn), jnp.float32) * std,
        "w_C": jax.random.normal(ks[3], (d_model, gn), jnp.float32) * std,
        "w_dt": jax.random.normal(ks[4], (d_model, h), jnp.float32) * std,
        "conv_x": jax.random.normal(ks[5], (ssm.conv_kernel, di), jnp.float32) * 0.1,
        "conv_B": jax.random.normal(ks[6], (ssm.conv_kernel, gn), jnp.float32) * 0.1,
        "conv_C": jax.random.normal(ks[7], (ssm.conv_kernel, gn), jnp.float32) * 0.1,
        "conv_bx": jnp.zeros((di,), jnp.float32),
        "conv_bB": jnp.zeros((gn,), jnp.float32),
        "conv_bC": jnp.zeros((gn,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))),  # softplus^-1(0.01)
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d_model), jnp.float32) * di ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [b, s, c]; w: [k, c]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    )
    return out + b.astype(x.dtype)


def ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk: int, *, return_state=False):
    """SSD scan. x: [b,s,h,p]; dt: [b,s,h] (post-softplus); a_log: [h];
    b_mat/c_mat: [b,s,n] (group-shared). Returns y [b,s,h,p] f32
    (+ final state [b,h,n,p] if return_state).
    Recurrence: h_t = exp(dt_t*A) h_{t-1} + dt_t * B_t (x) x_t; y_t = C_t . h_t
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))  # [h], negative
    da = dt.astype(jnp.float32) * a  # [b,s,h]
    xf = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]  # discretized
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    da_c = da.reshape(bsz, nc, chunk, h)
    cs = jnp.cumsum(da_c, axis=2)  # inclusive within-chunk
    x_c = xf.reshape(bsz, nc, chunk, h, p)
    b_c = bf.reshape(bsz, nc, chunk, n)
    c_c = cf.reshape(bsz, nc, chunk, n)

    # Intra-chunk: scores[i,j] = (C_i . B_j) * exp(cs_i - cs_j), j <= i.
    scores = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)  # head-shared part
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # [b,c,i,j,h]
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tril[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, x_c)

    # Chunk-final states: S[b,c,h,n,p] = sum_j B_j exp(cs_last - cs_j) x_j
    d2e = jnp.exp(cs[:, :, -1:, :] - cs)  # decay to end [b,c,j,h]
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", b_c, d2e, x_c)

    # Inter-chunk recurrence over chunk states.
    total = jnp.exp(cs[:, :, -1, :])  # [b,c,h] full-chunk decay

    def scan_fn(s_run, inp):
        tot, s_c = inp
        s_new = s_run * tot[:, :, None, None] + s_c
        return s_new, s_run  # emit state BEFORE this chunk

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    s_last, s_prev = jax.lax.scan(
        scan_fn, s0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_chunk, 1, 0))
    )
    s_prev = jnp.moveaxis(s_prev, 0, 1)  # [b,c,h,n,p]

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", c_c, s_prev, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    if return_state:
        return y, s_last
    return y


def _project(params, x, di, gn):
    z = x @ params["w_z"].astype(x.dtype)
    xs = x @ params["w_x"].astype(x.dtype)
    b_mat = x @ params["w_B"].astype(x.dtype)
    c_mat = x @ params["w_C"].astype(x.dtype)
    dt = x @ params["w_dt"].astype(x.dtype)
    return z, xs, b_mat, c_mat, dt


def ssm_forward(
    params: dict, x: jax.Array, d_model: int, ssm: SSMConfig,
    policy: ParallelPolicy = LOCAL, *, return_cache: bool = False,
):
    """Full-sequence Mamba-2 mixer. x: [b, s, d] -> [b, s, d]."""
    b, s, _ = x.shape
    di = ssm.d_inner(d_model)
    h = ssm.n_heads(d_model)
    gn = ssm.n_groups * ssm.d_state
    z, xs, b_mat, c_mat, dt = _project(params, x, di, gn)
    xs_pre = xs  # pre-conv stream, cached for decode
    b_pre, c_pre = b_mat, c_mat
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"], params["conv_bx"]))
    b_mat = jax.nn.silu(_causal_conv(b_mat, params["conv_B"], params["conv_bB"]))
    c_mat = jax.nn.silu(_causal_conv(c_mat, params["conv_C"], params["conv_bC"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    # pad the sequence to a chunk multiple with dt=0 steps: decay exp(0)=1
    # and zero discretized input leave the recurrent state untouched, so
    # return_state is exact; padded outputs are sliced off.
    chunk = min(ssm.chunk, s)
    pad = (-s) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    out = ssd_chunked(
        xs.reshape(b, s + pad, h, ssm.head_dim), dt, params["A_log"], b_mat, c_mat,
        chunk, return_state=return_cache,
    )
    y, state = out if return_cache else (out, None)
    if pad:
        y = y[:, :s]
        xs = xs[:, :s]
    y = y + params["D"][None, None, :, None] * xs.reshape(b, s, h, ssm.head_dim).astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm_w"], use_pallas=policy.use_pallas)
    y = y @ params["out_proj"].astype(x.dtype)
    if return_cache:
        k = ssm.conv_kernel
        pad = max(0, k - s)

        def last_k(a):
            a = a[:, -k:]
            if pad:
                a = jnp.pad(a, ((0, 0), (pad, 0), (0, 0)))
            return a

        conv_cache = jnp.concatenate(
            [last_k(xs_pre), last_k(b_pre), last_k(c_pre)], axis=-1
        ).astype(jnp.float32)
        return y, {"conv": conv_cache, "state": state}
    return y


# -- decode -------------------------------------------------------------------

def init_ssm_cache(d_model: int, ssm: SSMConfig, batch: int, dtype=jnp.float32) -> dict:
    h = ssm.n_heads(d_model)
    return {
        "conv": jnp.zeros((batch, ssm.conv_kernel, ssm.conv_dim(d_model)), dtype),
        "state": jnp.zeros((batch, h, ssm.d_state, ssm.head_dim), dtype),
    }


def ssm_decode(
    params: dict, x: jax.Array, cache: dict, d_model: int, ssm: SSMConfig,
    policy: ParallelPolicy = LOCAL,
) -> Tuple[jax.Array, dict]:
    """Single-token recurrent step. x: [b, 1, d]."""
    b = x.shape[0]
    di = ssm.d_inner(d_model)
    h = ssm.n_heads(d_model)
    gn = ssm.n_groups * ssm.d_state
    z, xs, b_mat, c_mat, dt = _project(params, x[:, 0], di, gn)
    # rolling conv state over the concatenated (x | B | C) pre-conv stream
    new_col = jnp.concatenate([xs, b_mat, c_mat], axis=-1)
    conv = jnp.concatenate(
        [cache["conv"][:, 1:], new_col[:, None].astype(cache["conv"].dtype)], axis=1
    )
    conv_w = jnp.concatenate([params["conv_x"], params["conv_B"], params["conv_C"]], axis=1)
    conv_b = jnp.concatenate([params["conv_bx"], params["conv_bB"], params["conv_bC"]])
    mixed = jnp.einsum("bkc,kc->bc", conv.astype(jnp.float32), conv_w) + conv_b
    mixed = jax.nn.silu(mixed).astype(x.dtype)
    xs, b_mat, c_mat = jnp.split(mixed, [di, di + gn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [b,h]
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a)  # [b,h]
    xh = xs.reshape(b, h, ssm.head_dim).astype(jnp.float32) * dt[..., None]
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", b_mat.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhnp->bhp", c_mat.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xs.reshape(b, h, ssm.head_dim).astype(jnp.float32)
    y = y.reshape(b, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm_w"], use_pallas=policy.use_pallas)
    out = (y @ params["out_proj"].astype(x.dtype))[:, None]
    return out, {"conv": conv, "state": state}
