"""RG-LRU recurrent block (Griffin / RecurrentGemma) + decode state.

Block: two input projections; one branch goes conv1d -> RG-LRU, the other is
a GeLU gate; elementwise product, then output projection. The RG-LRU diag
recurrence  h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t)  with
a_t = exp(-c * softplus(L) * r_t) is computed with an associative scan
(log-depth; XLA maps it onto tree reductions).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


RGLRU_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0          # 0 -> same as d_model
    conv_kernel: int = 4

    def width(self, d_model: int) -> int:
        return self.d_rnn or d_model


def init_rglru_params(key, d_model: int, cfg: RGLRUConfig) -> dict:
    w = cfg.width(d_model)
    ks = jax.random.split(key, 6)
    std = d_model ** -0.5
    # Lambda init so a^(1/c) ~ U[0.9, 0.999] as in the Griffin paper.
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))  # softplus^-1(-log u)
    return {
        "w_x": jax.random.normal(ks[0], (d_model, w), jnp.float32) * std,
        "w_gate": jax.random.normal(ks[1], (d_model, w), jnp.float32) * std,
        "conv_w": jax.random.normal(ks[2], (cfg.conv_kernel, w), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_r": jax.random.normal(ks[3], (w, w), jnp.float32) * w ** -0.5,
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": jax.random.normal(ks[5], (w, w), jnp.float32) * w ** -0.5,
        "b_i": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "w_out": jax.random.normal(ks[0], (w, d_model), jnp.float32) * w ** -0.5,
    }


def _causal_conv(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k))
    return out + b.astype(x.dtype)


def _rglru_scan(x, r, i, lam):
    """x/r/i: [b, s, w] f32. Returns h: [b, s, w]."""
    log_a = -RGLRU_C * jax.nn.softplus(lam) * r  # [b,s,w], negative
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def rglru_forward(params: dict, x: jax.Array, cfg: RGLRUConfig, d_model: int) -> jax.Array:
    """x: [b, s, d] -> [b, s, d]."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype), approximate=True)
    u = x @ params["w_x"].astype(x.dtype)
    u = _causal_conv(u, params["conv_w"], params["conv_b"])
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_r"] + params["b_r"])
    i = jax.nn.sigmoid(uf @ params["w_i"] + params["b_i"])
    h = _rglru_scan(uf, r, i, params["lambda"]).astype(x.dtype)
    return (h * gate) @ params["w_out"].astype(x.dtype)


def init_rglru_cache(d_model: int, cfg: RGLRUConfig, batch: int, dtype=jnp.float32) -> dict:
    w = cfg.width(d_model)
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel, w), dtype),
        "h": jnp.zeros((batch, w), dtype),
    }


def rglru_decode(
    params: dict, x: jax.Array, cache: dict, cfg: RGLRUConfig, d_model: int
) -> Tuple[jax.Array, dict]:
    """x: [b, 1, d]."""
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate"].astype(x.dtype), approximate=True)
    u = x[:, 0] @ params["w_x"].astype(x.dtype)
    conv = jnp.concatenate([cache["conv"][:, 1:], u[:, None].astype(cache["conv"].dtype)], axis=1)
    u = jnp.einsum("bkc,kc->bc", conv.astype(jnp.float32), params["conv_w"]) + params["conv_b"]
    r = jax.nn.sigmoid(u @ params["w_r"] + params["b_r"])
    i = jax.nn.sigmoid(u @ params["w_i"] + params["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    h = a * cache["h"] + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (i * u)
    y = (h.astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y[:, None], {"conv": conv, "h": h}
