"""Decoder-LM engine: dense / MoE / SSM / hybrid families.

One functional implementation drives all decoder-only assigned archs:
  * ``init_lm_params``  — stacked per-layer params (scan-over-layers keeps
    the HLO compact: one layer body + loop, critical for 512-device AOT
    compiles of 64-layer models);
  * ``lm_loss``         — training forward + chunked cross-entropy;
  * ``lm_prefill``      — full-sequence forward that also emits the serve
    cache (KV / MLA-latent / SSM-state / window ring, per family);
  * ``lm_decode_step``  — one-token step over the stacked cache;
  * ``param_specs``     — PartitionSpecs for every parameter (TP over the
    ``model`` axis; specs auto-replicate non-divisible dims).

Whisper (encdec family) lives in models/whisper.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_lib
from repro.models import layers, moe as moe_lib, rglru as rglru_lib, ssm as ssm_lib
from repro.models.policy import ParallelPolicy, LOCAL


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_mlp(key, d_model: int, d_ff: int, act: str) -> dict:
    ks = jax.random.split(key, 3)
    std_d, std_f = d_model ** -0.5, d_ff ** -0.5
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": jax.random.normal(ks[0], (d_model, d_ff), jnp.float32) * std_d,
            "w_up": jax.random.normal(ks[1], (d_model, d_ff), jnp.float32) * std_d,
            "w_down": jax.random.normal(ks[2], (d_ff, d_model), jnp.float32) * std_f,
        }
    return {
        "w1": jax.random.normal(ks[0], (d_model, d_ff), jnp.float32) * std_d,
        "b1": jnp.zeros((d_ff,), jnp.float32),
        "w2": jax.random.normal(ks[1], (d_ff, d_model), jnp.float32) * std_f,
        "b2": jnp.zeros((d_model,), jnp.float32),
    }


def _init_layer(key, cfg, kind: str) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"ln1": jnp.ones((d,), jnp.float32)}
    if kind == "ssm":
        p["mixer"] = ssm_lib.init_ssm_params(ks[0], d, cfg.ssm)
        return p
    if kind == "rec":
        p["mixer"] = rglru_lib.init_rglru_params(ks[0], d, cfg.rglru)
        p["ln2"] = jnp.ones((d,), jnp.float32)
        p["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_act)
        return p
    # attention-bearing layers
    if cfg.mla is not None:
        p["attn"] = attn_lib.init_mla_params(ks[0], cfg)
    else:
        p["attn"] = attn_lib.init_attn_params(ks[0], cfg)
    p["ln2"] = jnp.ones((d,), jnp.float32)
    if kind == "moe":
        p["moe"] = moe_lib.init_moe_params(ks[1], d, cfg.moe)
    elif kind == "dense0":
        p["mlp"] = _init_mlp(ks[1], d, cfg.moe.first_dense_ff, cfg.mlp_act)
    else:
        p["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_act)
    return p


def init_lm_params(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    v, d = cfg.vocab, cfg.d_model
    params = {
        "embed": jax.random.normal(ks[0], (v, d), jnp.float32) * d ** -0.5,
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": jax.random.normal(ks[1], (d, v), jnp.float32) * d ** -0.5,
    }
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        n_super = cfg.n_layers // len(pat)
        tail = cfg.n_layers - n_super * len(pat)
        sb_keys = jax.random.split(ks[2], n_super)

        def init_super(k):
            kk = jax.random.split(k, len(pat))
            return {f"b{i}_{kind}": _init_layer(kk[i], cfg, kind) for i, kind in enumerate(pat)}

        params["superblocks"] = jax.vmap(init_super)(sb_keys)
        tk = jax.random.split(ks[3], max(tail, 1))
        params["tail"] = [
            _init_layer(tk[i], cfg, pat[i % len(pat)]) for i in range(tail)
        ]
        return params
    if kinds and kinds[0] == "dense0":
        params["layer0"] = _init_layer(ks[2], cfg, "dense0")
        rest = kinds[1:]
    else:
        params["layer0"] = None
        rest = kinds
    layer_keys = jax.random.split(ks[4], len(rest))
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg, rest[0]))(layer_keys)
    return params


# ---------------------------------------------------------------------------
# Param partition specs (TP over the model axis).
# ---------------------------------------------------------------------------

def _mlp_specs(act: str, mx: str) -> dict:
    if act in ("swiglu", "geglu"):
        return {"w_gate": P(None, mx), "w_up": P(None, mx), "w_down": P(mx, None)}
    return {"w1": P(None, mx), "b1": P(mx), "w2": P(mx, None), "b2": P()}


def _layer_specs(cfg, kind: str, mx: str) -> dict:
    s = {"ln1": P()}
    if kind == "ssm":
        s["mixer"] = {
            "w_z": P(None, mx), "w_x": P(None, mx), "w_B": P(), "w_C": P(),
            "w_dt": P(), "conv_x": P(None, mx), "conv_B": P(), "conv_C": P(),
            "conv_bx": P(mx), "conv_bB": P(), "conv_bC": P(),
            "A_log": P(), "D": P(), "dt_bias": P(), "norm_w": P(mx),
            "out_proj": P(mx, None),
        }
        return s
    if kind == "rec":
        # RG-LRU mixers are REPLICATED (pure data parallelism): the
        # recurrence is elementwise over the width dim, but TP-sharding the
        # square gate matmuls forces an all-reduce of f32 activations per
        # layer (measured 80+ GB/step wire on the 16x16 mesh — see
        # EXPERIMENTS §Perf hillclimb 2). The mixers are small (~39 M
        # params/layer), so replication + ZeRO-1 moments is the better
        # trade; the adjacent MLPs stay TP-sharded.
        s["mixer"] = {
            "w_x": P(), "w_gate": P(),
            "conv_w": P(), "conv_b": P(),
            "w_r": P(), "b_r": P(), "w_i": P(), "b_i": P(),
            "lambda": P(), "w_out": P(),
        }
        s["ln2"] = P()
        s["mlp"] = _mlp_specs(cfg.mlp_act, mx)
        return s
    if cfg.mla is not None:
        s["attn"] = {
            "wq": P(None, mx), "w_dkv": P(None, None), "kv_norm": P(),
            "k_up": P(None, mx), "v_up": P(None, mx), "wo": P(mx, None),
        }
    else:
        a = {"wq": P(None, mx), "wk": P(None, mx), "wv": P(None, mx), "wo": P(mx, None)}
        if cfg.qkv_bias:
            a.update({"bq": P(mx), "bk": P(mx), "bv": P(mx)})
        if cfg.qk_norm:
            a.update({"q_norm": P(), "k_norm": P()})
        s["attn"] = a
    s["ln2"] = P()
    if kind == "moe":
        s["moe"] = moe_lib.moe_param_specs(cfg.moe, mx)
    else:
        s["mlp"] = _mlp_specs(cfg.mlp_act, mx)
    return s


def _stack_specs(spec_tree):
    """Prefix every leaf spec with None for the stacked layer dim."""
    return jax.tree.map(
        lambda p: P(None, *p), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def param_specs(cfg, policy: ParallelPolicy) -> dict:
    mx = policy.model_axis
    v = cfg.vocab
    p_model = policy.model_size()
    head_spec = P(None, mx) if v % p_model == 0 else P(None, None)
    specs = {
        "embed": P(None, mx) if cfg.d_model % p_model == 0 else P(None, None),
        "final_norm": P(),
        "lm_head": head_spec,
    }
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        n_super = cfg.n_layers // len(pat)
        tail = cfg.n_layers - n_super * len(pat)
        sb = {
            f"b{i}_{kind}": _layer_specs(cfg, kind, mx) for i, kind in enumerate(pat)
        }
        specs["superblocks"] = _stack_specs(sb)
        specs["tail"] = [_layer_specs(cfg, pat[i % len(pat)], mx) for i in range(tail)]
        return specs
    if kinds and kinds[0] == "dense0":
        specs["layer0"] = _layer_specs(cfg, "dense0", mx)
        rest_kind = kinds[1]
    else:
        specs["layer0"] = None
        rest_kind = kinds[0]
    specs["layers"] = _stack_specs(_layer_specs(cfg, rest_kind, mx))
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _norm(x, w, cfg, policy):
    if cfg.norm == "ln":
        return layers.layer_norm(x, w, jnp.zeros_like(w), eps=cfg.norm_eps)
    return layers.rms_norm(x, w, eps=cfg.norm_eps, use_pallas=policy.use_pallas)


def _apply_layer(x, lp, kind, cfg, policy, positions):
    """One transformer block; returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(x, lp["ln1"], cfg, policy)
    if kind == "ssm":
        x = x + ssm_lib.ssm_forward(lp["mixer"], h, cfg.d_model, cfg.ssm, policy)
        return policy.shard_act(x), aux
    if kind == "rec":
        x = x + rglru_lib.rglru_forward(lp["mixer"], h, cfg.rglru, cfg.d_model)
    elif cfg.mla is not None:
        x = x + attn_lib.mla_forward(lp["attn"], h, cfg, policy, positions=positions)
    else:
        x = x + attn_lib.attn_forward(lp["attn"], h, cfg, policy, positions=positions)
    x = policy.shard_act(x)
    h = _norm(x, lp["ln2"], cfg, policy)
    if kind == "moe":
        y, aux = moe_lib.moe_apply(lp["moe"], h, cfg.moe, policy)
        x = x + y
    elif cfg.mlp_act in ("swiglu", "geglu"):
        x = x + layers.glu_mlp(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"], act=cfg.mlp_act)
    else:
        x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"], act=cfg.mlp_act)
    return policy.shard_act(x), aux


def _remat(body, policy):
    if not policy.remat:
        return body
    if policy.remat_policy == "dots":
        return jax.checkpoint(body, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(body)


def _embed_in(params, tokens, cfg, policy):
    x = layers.embed(params["embed"], tokens, scale_by_sqrt_dim=cfg.embed_scale)
    x = x.astype(cfg.activation_dtype)
    return policy.shard_act(x)


def lm_hidden(params, tokens, cfg, policy: ParallelPolicy = LOCAL):
    """Token ids -> final-norm hidden states [b, s, d]; returns (h, aux)."""
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = _embed_in(params, tokens, cfg, policy)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")

        def super_body(carry, sb):
            x, aux = carry
            for i, kind in enumerate(pat):
                x, a = _apply_layer(x, sb[f"b{i}_{kind}"], kind, cfg, policy, positions)
                aux = aux + a
            return (x, aux), None

        body = _remat(super_body, policy)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["superblocks"])
        for i, lp in enumerate(params["tail"]):
            x, a = _apply_layer(x, lp, pat[i % len(pat)], cfg, policy, positions)
            aux_total = aux_total + a
    else:
        kinds = cfg.layer_kinds()
        if params.get("layer0") is not None:
            x, a = _apply_layer(x, params["layer0"], "dense0", cfg, policy, positions)
            aux_total = aux_total + a
            rest_kind = kinds[1]
        else:
            rest_kind = kinds[0]

        def body(carry, lp):
            x, aux = carry
            x, a = _apply_layer(x, lp, rest_kind, cfg, policy, positions)
            return (x, aux + a), None

        body = _remat(body, policy)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])

    h = _norm(x, params["final_norm"], cfg, policy)
    return h, aux_total


def lm_loss(params, batch: dict, cfg, policy: ParallelPolicy = LOCAL):
    """Training loss. batch: {"tokens": [b,s], "targets": [b,s]}."""
    h, aux = lm_hidden(params, batch["tokens"], cfg, policy)
    xent = layers.chunked_cross_entropy(
        h, params["lm_head"], batch["targets"], policy=policy if policy.distributed else None
    )
    return xent + aux, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode over stacked caches.
# ---------------------------------------------------------------------------

def use_split_cache(cfg, policy: ParallelPolicy) -> bool:
    """Split prefix/tail caches for all distributed attention decode: the
    big prefix stays READ-ONLY per step (flows through the layer scan as an
    xs input — no per-layer output copy, no DUS across sharded dims) and
    appends go to a small replicated tail ring flushed by the engine."""
    return policy.distributed and cfg.window is None


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, policy: ParallelPolicy = LOCAL):
    """Build the zeroed, stacked cache pytree for this family."""
    split = use_split_cache(cfg, policy)

    def one(kind):
        if kind == "ssm":
            return ssm_lib.init_ssm_cache(cfg.d_model, cfg.ssm, batch)
        if kind == "rec":
            return rglru_lib.init_rglru_cache(cfg.d_model, cfg.rglru, batch)
        if cfg.mla is not None:
            return attn_lib.init_mla_cache(cfg, batch, max_len, dtype, split=split)
        return attn_lib.init_kv_cache(
            cfg, batch, max_len, dtype, split=split, quant=policy.kv_quant
        )

    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        n_super = cfg.n_layers // len(pat)
        tail = cfg.n_layers - n_super * len(pat)
        stack = lambda tree, n: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree
        )
        return {
            "superblocks": {
                f"b{i}_{kind}": stack(one(kind), n_super) for i, kind in enumerate(pat)
            },
            "tail": [one(pat[i % len(pat)]) for i in range(tail)],
        }
    if kinds and kinds[0] == "dense0":
        rest = len(kinds) - 1
        return {
            "layer0": one("attn"),
            "layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (rest,) + a.shape).copy(), one(kinds[1])
            ),
        }
    n = len(kinds)
    return {
        "layer0": None,
        "layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one(kinds[0])
        ),
    }


def cache_specs(cfg, policy: ParallelPolicy):
    """PartitionSpec tree matching ``init_cache`` (stacked layer dim first).

    KV heads shard over the model axis when divisible; batch over dp axes
    (dropped automatically at use when the cell's batch is not divisible).
    """
    mx = policy.model_axis
    dp = policy.dp_axes
    p_size = policy.model_size()

    def attn_spec():
        if cfg.kv_heads % p_size == 0:
            s = P(dp, mx, None, None)  # head-sharded prefix
            sc = P(dp, mx, None)
        else:
            # Domain decomposition over the cache's sequence dim (the
            # paper's insight applied to decode): each model shard owns a
            # contiguous read-only KV chunk; softmax combine is a psum.
            s = P(dp, None, mx, None)
            sc = P(dp, None, mx)
        if use_split_cache(cfg, policy):
            t = P(dp, None, None, None)
            spec = {"k": s, "v": s, "tk": t, "tv": t}
            if policy.kv_quant:
                spec["k_scale"] = sc
                spec["v_scale"] = sc
            return spec
        return {"k": s, "v": s}

    def mla_spec():
        s = {"ckv": P(dp, mx, None), "kr": P(dp, mx, None)}  # seq-sharded prefix
        if use_split_cache(cfg, policy):
            s["tckv"] = P(dp, None, None)
            s["tkr"] = P(dp, None, None)
        return s

    def ssm_spec():
        h = cfg.ssm.n_heads(cfg.d_model)
        return {
            "conv": P(dp, None, None),
            "state": P(dp, mx if h % p_size == 0 else None, None, None),
        }

    def rec_spec():
        w = cfg.rglru.width(cfg.d_model)
        return {
            "conv": P(dp, None, mx if w % p_size == 0 else None),
            "h": P(dp, mx if w % p_size == 0 else None),
        }

    def one(kind):
        if kind == "ssm":
            return ssm_spec()
        if kind == "rec":
            return rec_spec()
        if cfg.mla is not None:
            return mla_spec()
        return attn_spec()

    def stacked(tree):
        return jax.tree.map(
            lambda p: P(None, *p), tree, is_leaf=lambda x: isinstance(x, P)
        )

    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        n_super = cfg.n_layers // len(pat)
        tail = cfg.n_layers - n_super * len(pat)
        return {
            "superblocks": {
                f"b{i}_{kind}": stacked(one(kind)) for i, kind in enumerate(pat)
            },
            "tail": [one(pat[i % len(pat)]) for i in range(tail)],
        }
    if kinds and kinds[0] == "dense0":
        return {"layer0": one("attn"), "layers": stacked(one(kinds[1]))}
    return {"layer0": None, "layers": stacked(one(kinds[0]))}


def cache_batch_axes(cache):
    """Pytree of ints: which axis of each cache leaf is the batch/slot dim.
    Stacked per-layer subtrees ('layers', 'superblocks') put the layer dim
    first, so batch is axis 1 there; unstacked leaves have batch at 0."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    axes = []
    for path, _ in flat:
        keys = [getattr(p, "key", None) for p in path]
        axes.append(1 if ("layers" in keys or "superblocks" in keys) else 0)
    return jax.tree_util.tree_unflatten(treedef, axes)


def _decode_layer(x, lp, cache, index, kind, cfg, policy):
    h = _norm(x, lp["ln1"], cfg, policy)
    if kind == "ssm":
        y, new_cache = ssm_lib.ssm_decode(lp["mixer"], h, cache, cfg.d_model, cfg.ssm, policy)
        return policy.shard_act(x + y), new_cache
    if kind == "rec":
        y, new_cache = rglru_lib.rglru_decode(lp["mixer"], h, cache, cfg.rglru, cfg.d_model)
        x = x + y
    elif cfg.mla is not None:
        y, new_cache = attn_lib.mla_decode(lp["attn"], h, cache, index, cfg, policy)
        x = x + y
    else:
        y, new_cache = attn_lib.attn_decode(lp["attn"], h, cache, index, cfg, policy)
        x = x + y
    h = _norm(x, lp["ln2"], cfg, policy)
    if kind == "moe":
        y, _ = moe_lib.moe_apply(lp["moe"], h, cfg.moe, policy)
        x = x + y
    elif cfg.mlp_act in ("swiglu", "geglu"):
        x = x + layers.glu_mlp(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"], act=cfg.mlp_act)
    else:
        x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"], act=cfg.mlp_act)
    return policy.shard_act(x), new_cache


def lm_decode_step(params, token, cache, index, cfg, policy: ParallelPolicy = LOCAL):
    """One decode step. token: [b, 1] int32; index: scalar int32 (tokens so
    far in cache). Returns (logits [b, vocab], new_cache)."""
    x = _embed_in(params, token, cfg, policy)

    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")

        def super_body(x, inp):
            sb, sb_cache = inp
            new_caches = {}
            for i, kind in enumerate(pat):
                name = f"b{i}_{kind}"
                x, nc = _decode_layer(x, sb[name], sb_cache[name], index, kind, cfg, policy)
                new_caches[name] = nc
            return x, new_caches

        x, new_sb = jax.lax.scan(super_body, x, (params["superblocks"], cache["superblocks"]))
        new_tail = []
        for i, lp in enumerate(params["tail"]):
            kind = pat[i % len(pat)]
            x, nc = _decode_layer(x, lp, cache["tail"][i], index, kind, cfg, policy)
            new_tail.append(nc)
        new_cache = {"superblocks": new_sb, "tail": new_tail}
    else:
        kinds = cfg.layer_kinds()
        new_cache = {"layer0": None}
        if params.get("layer0") is not None:
            x, nc0 = _decode_layer(x, params["layer0"], cache["layer0"], index, "dense0", cfg, policy)
            new_cache["layer0"] = nc0
            rest_kind = kinds[1]
        else:
            rest_kind = kinds[0]

        layer_cache = cache["layers"]
        tail_keys = [k for k in ("tk", "tv", "tckv", "tkr") if isinstance(layer_cache, dict) and k in layer_cache]

        if policy.unroll_decode:
            n = len(cfg.layer_kinds()) - (1 if params.get("layer0") is not None else 0)
            outs = []
            for i in range(n):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                # Joint barrier ties layer i's cache slice to the running
                # residual: without it the per-layer slice converts depend
                # only on the cache param, so the scheduler hoists ALL of
                # them ahead of the layer chain and their buffers coexist
                # (~n_layers x slice bytes of temp).
                lc, x = jax.lax.optimization_barrier(
                    (jax.tree.map(lambda a: a[i], layer_cache), x)
                )
                x, nc = _decode_layer(x, lp, lc, index, rest_kind, cfg, policy)
                outs.append({k: nc[k] for k in tail_keys} if tail_keys else nc)
            new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            def body(x, inp):
                lp, lc = inp
                x, nc = _decode_layer(x, lp, lc, index, rest_kind, cfg, policy)
                if tail_keys:
                    nc = {k: nc[k] for k in tail_keys}  # prefix is read-only xs
                return x, nc

            x, new_layers = jax.lax.scan(body, x, (params["layers"], layer_cache))
        if tail_keys:
            new_layers = {
                **{k: v for k, v in layer_cache.items() if k not in tail_keys},
                **new_layers,
            }
        new_cache["layers"] = new_layers

    h = _norm(x, params["final_norm"], cfg, policy)
    logits = layers.logits_last(h[:, 0], params["lm_head"])
    return logits, new_cache


def lm_prefill(params, tokens, cfg, policy: ParallelPolicy = LOCAL, max_len: Optional[int] = None):
    """Process a prompt, returning (last-token logits, cache at len(prompt)).

    The cache is sized to ``max_len`` (defaults to prompt length). Attention
    caches hold the prompt's k/v; recurrent families hold final states.
    """
    b, s = tokens.shape
    max_len = max_len or s
    positions = jnp.arange(s)
    x = _embed_in(params, tokens, cfg, policy)

    def prefill_layer(x, lp, kind):
        h = _norm(x, lp["ln1"], cfg, policy)
        if kind == "ssm":
            y, cache = ssm_lib.ssm_forward(lp["mixer"], h, cfg.d_model, cfg.ssm, policy, return_cache=True)
            return policy.shard_act(x + y), cache
        if kind == "rec":
            y, cache = _rglru_prefill(lp["mixer"], h, cfg)
            x = x + y
        elif cfg.mla is not None:
            y, cache = _mla_prefill(lp["attn"], h, cfg, policy, positions, max_len)
            x = x + y
        else:
            y, cache = _attn_prefill(lp["attn"], h, cfg, policy, positions, max_len)
            x = x + y
        h = _norm(x, lp["ln2"], cfg, policy)
        if kind == "moe":
            y, _ = moe_lib.moe_apply(lp["moe"], h, cfg.moe, policy)
            x = x + y
        elif cfg.mlp_act in ("swiglu", "geglu"):
            x = x + layers.glu_mlp(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"], act=cfg.mlp_act)
        else:
            x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"], act=cfg.mlp_act)
        return policy.shard_act(x), cache

    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")

        def super_body(x, sb):
            caches = {}
            for i, kind in enumerate(pat):
                name = f"b{i}_{kind}"
                x, caches[name] = prefill_layer(x, sb[name], kind)
            return x, caches

        x, sb_caches = jax.lax.scan(super_body, x, params["superblocks"])
        tail_caches = []
        for i, lp in enumerate(params["tail"]):
            x, c = prefill_layer(x, lp, pat[i % len(pat)])
            tail_caches.append(c)
        cache = {"superblocks": sb_caches, "tail": tail_caches}
    else:
        kinds = cfg.layer_kinds()
        cache = {"layer0": None}
        if params.get("layer0") is not None:
            x, c0 = prefill_layer(x, params["layer0"], "dense0")
            cache["layer0"] = c0
            rest_kind = kinds[1]
        else:
            rest_kind = kinds[0]

        def body(x, lp):
            return prefill_layer(x, lp, rest_kind)

        x, layer_caches = jax.lax.scan(body, x, params["layers"])
        cache["layers"] = layer_caches

    h = _norm(x, params["final_norm"], cfg, policy)
    logits = layers.logits_last(h[:, -1], params["lm_head"])
    return logits, cache


def _attn_prefill(p, h, cfg, policy, positions, max_len):
    b, s, _ = h.shape
    q, k, v = attn_lib._project_qkv(p, h, cfg, positions)
    q = q.swapaxes(1, 2)
    kt, vt = k.swapaxes(1, 2), v.swapaxes(1, 2)
    if policy.distributed:
        qp, kp, vp, h_real = attn_lib._pad_heads(q, kt, vt, policy.model_size())
    else:
        qp, kp, vp, h_real = q, kt, vt, cfg.n_heads
    qp = policy.shard(qp, policy.dp_axes, policy.model_axis, None, None)
    kp = policy.shard(kp, policy.dp_axes, policy.model_axis, None, None)
    vp = policy.shard(vp, policy.dp_axes, policy.model_axis, None, None)
    from repro.kernels.flash_attention import flash_attention
    if cfg.window is not None and s > cfg.window:
        o = attn_lib._windowed_attention(qp, kp, vp, cfg.window)
    else:
        o = flash_attention(qp, kp, vp, causal=True, use_pallas=policy.use_pallas, chunk_k=min(1024, s))
    o = o[:, :h_real].swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim_)
    out = o @ p["wo"].astype(h.dtype)
    # cache
    if cfg.window is not None:
        w = min(cfg.window, max_len)
        if s < w:
            # short prompt: tokens already sit at ring slots 0..s-1
            kc = jnp.pad(kt, ((0, 0), (0, 0), (0, w - s), (0, 0)))
            vc = jnp.pad(vt, ((0, 0), (0, 0), (0, w - s), (0, 0)))
        else:
            kc, vc = kt[:, :, -w:], vt[:, :, -w:]
            shift = s % w
            kc = jnp.roll(kc, shift, axis=2)  # ring layout: slot = pos % window
            vc = jnp.roll(vc, shift, axis=2)
    else:
        pad = max_len - s
        kc = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    dtype = jnp.bfloat16 if h.dtype == jnp.bfloat16 else h.dtype
    if use_split_cache(cfg, policy) and cfg.window is None:
        tail = jnp.zeros((b, cfg.kv_heads, attn_lib.TAIL_LEN, cfg.head_dim_), dtype)
        if policy.kv_quant:
            kq, ks = attn_lib.quantize_kv(kc)
            vq, vs = attn_lib.quantize_kv(vc)
            return out, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
                         "tk": tail, "tv": tail}
        return out, {"k": kc.astype(dtype), "v": vc.astype(dtype), "tk": tail, "tv": tail}
    return out, {"k": kc.astype(dtype), "v": vc.astype(dtype)}


def _mla_prefill(p, h, cfg, policy, positions, max_len):
    m = cfg.mla
    b, s, _ = h.shape
    out = attn_lib.mla_forward(p, h, cfg, policy, positions=positions)
    q_nope, q_rope, ckv, k_rope = attn_lib._mla_qkr(p, h, cfg, positions)
    pad = max_len - s
    ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
    kr = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return out, {"ckv": ckv.astype(jnp.bfloat16), "kr": kr.astype(jnp.bfloat16)}


def _rglru_prefill(p, h, cfg):
    out = rglru_lib.rglru_forward(p, h, cfg.rglru, cfg.d_model)
    # recompute the final state cheaply for the cache
    x = h
    u = x @ p["w_x"].astype(x.dtype)
    k = p["conv_w"].shape[0]
    conv_cache = u[:, -k:].astype(jnp.float32)
    if conv_cache.shape[1] < k:  # prompt shorter than the conv kernel
        conv_cache = jnp.pad(conv_cache, ((0, 0), (k - conv_cache.shape[1], 0), (0, 0)))
    u_conv = rglru_lib._causal_conv(u, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    r = jax.nn.sigmoid(u_conv @ p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(u_conv @ p["w_i"] + p["b_i"])
    hseq = rglru_lib._rglru_scan(u_conv, r, i, p["lambda"])
    return out, {"conv": conv_cache, "h": hseq[:, -1]}
