"""Shared LM layers: norms, RoPE, embeddings, MLPs, chunked cross-entropy."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import rmsnorm as rmsnorm_op


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, *, eps=1e-6, use_pallas=False, plus_one=False):
    if plus_one:  # gemma convention: weight stored as (w - 1)
        w = 1.0 + w.astype(jnp.float32)
    return rmsnorm_op(x, w, eps=eps, use_pallas=use_pallas)


def layer_norm(x, w, b, *, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w + b
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (GPT-NeoX convention; optional partial fraction
# as in ChatGLM's 2D-RoPE-descended scheme which rotates half the head dim).
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, theta=10000.0, fraction=1.0):
    """x: [b, s, h, d]; positions: [s] or [b, s] token positions."""
    d = x.shape[-1]
    inv, rot = rope_frequencies(d, theta, fraction)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv  # [b, s, rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = jnp.split(xr, 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(table, tokens, *, scale_by_sqrt_dim=False):
    """table: [V, D]; tokens: int [b, s] -> [b, s, D]."""
    x = jnp.take(table, tokens, axis=0)
    if scale_by_sqrt_dim:
        x = x * jnp.asarray(table.shape[-1] ** 0.5, x.dtype)
    return x


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp(x, w_gate, w_up, w_down, *, act: str = "swiglu"):
    """Gated MLP: swiglu (silu gate) or geglu (tanh-gelu gate, gemma)."""
    g = x @ w_gate.astype(x.dtype)
    u = x @ w_up.astype(x.dtype)
    if act == "swiglu":
        g = jax.nn.silu(g)
    elif act == "geglu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        raise ValueError(act)
    return (g * u) @ w_down.astype(x.dtype)


def gelu_mlp(x, w1, b1, w2, b2, *, act: str = "gelu"):
    h = x @ w1.astype(x.dtype) + b1.astype(x.dtype)
    if act == "gelu":
        h = jax.nn.gelu(h, approximate=False)
    elif act == "relu2":  # nemotron/minitron squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return h @ w2.astype(x.dtype) + b2.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy.
#
# Never materializes [B, S, V] logits: scans the sequence in chunks, so peak
# logit memory is B*chunk*V (sharded over the model axis on the vocab dim).
# This is the memory-roofline fix that makes 256k-vocab archs (gemma,
# minitron, recurrentgemma) trainable at seq 4k on 16 GB chips.
# ---------------------------------------------------------------------------

def chunked_cross_entropy(
    h: jax.Array,           # [b, s, d] final hidden states
    lm_head: jax.Array,     # [d, v]
    targets: jax.Array,     # [b, s] int32
    *,
    chunk: int = 512,
    policy=None,
) -> jax.Array:
    b, s, d = h.shape
    v = lm_head.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, n, chunk), 1, 0)

    def body(total, inp):
        hx, tx = inp  # [b, chunk, d], [b, chunk]
        logits = (hx @ lm_head.astype(hx.dtype)).astype(jnp.float32)
        if policy is not None:
            logits = policy.shard(logits, policy.dp_axes, None, policy.model_axis)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        return total + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (b * s)


def logits_last(h_last: jax.Array, lm_head: jax.Array) -> jax.Array:
    """Decode-time logits for the last position only. h_last: [b, d]."""
    return (h_last @ lm_head.astype(h_last.dtype)).astype(jnp.float32)
