"""Mixture-of-Experts with expert-parallel all-to-all dispatch.

The EP dispatch is the paper's repartition primitive applied to the expert
dimension: tokens are scattered into per-expert capacity buffers locally,
then a single all-to-all moves each expert's buffer to its owning device
(exactly R_{token-shard -> expert-shard}), expert FFNs run locally, and the
adjoint all-to-all brings results home. No [T, E, C] one-hot tensor is ever
materialized — routing positions come from a cumsum over a [T, E] mask, so
the approach scales to 32k sequences.

DeepSeek specifics supported: shared experts (dense FFN alongside routed),
fine-grained experts, optional top-k renormalization, first-layer dense,
and the standard load-balance auxiliary loss.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import compat
from repro.models import layers
from repro.models.policy import ParallelPolicy, LOCAL


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int          # per-expert FFN width
    n_shared: int = 0      # shared ("always-on") experts, deepseek-style
    first_dense_ff: int = 0  # layer-0 dense FFN width (0 = layer 0 is MoE too)
    norm_topk: bool = False
    capacity_factor: float = 1.25
    aux_coef: float = 0.001


def init_moe_params(key, d_model: int, moe: MoEConfig) -> dict:
    ks = jax.random.split(key, 7)
    e, f = moe.n_experts, moe.d_expert
    std_d = d_model ** -0.5
    std_f = f ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d_model, e), jnp.float32) * std_d,
        "w_gate": jax.random.normal(ks[1], (e, d_model, f), jnp.float32) * std_d,
        "w_up": jax.random.normal(ks[2], (e, d_model, f), jnp.float32) * std_d,
        "w_down": jax.random.normal(ks[3], (e, f, d_model), jnp.float32) * std_f,
    }
    if moe.n_shared:
        fs = moe.n_shared * f
        p["shared"] = {
            "w_gate": jax.random.normal(ks[4], (d_model, fs), jnp.float32) * std_d,
            "w_up": jax.random.normal(ks[5], (d_model, fs), jnp.float32) * std_d,
            "w_down": jax.random.normal(ks[6], (fs, d_model), jnp.float32) * (fs ** -0.5),
        }
    return p


def moe_param_specs(moe: MoEConfig, model_axis: str = "model") -> dict:
    p = {
        "router": P(),
        "w_gate": P(model_axis, None, None),
        "w_up": P(model_axis, None, None),
        "w_down": P(model_axis, None, None),
    }
    if moe.n_shared:
        p["shared"] = {
            "w_gate": P(None, model_axis),
            "w_up": P(None, model_axis),
            "w_down": P(model_axis, None),
        }
    return p


def _route(x_flat: jax.Array, router_w: jax.Array, moe: MoEConfig):
    """x_flat: [T, D] -> (top idx [T,k], top weights [T,k], probs [T,E])."""
    logits = (x_flat @ router_w.astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, moe.top_k)
    if moe.norm_topk:
        topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)
    return topi, topv.astype(x_flat.dtype), probs


def _aux_stats(topi: jax.Array, probs: jax.Array, moe: MoEConfig):
    """Per-shard sufficient statistics for the load-balance loss."""
    e = moe.n_experts
    counts = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=(0, 1))
    prob_sum = jnp.sum(probs, axis=0)
    n = jnp.asarray(probs.shape[0], jnp.float32)
    return counts, prob_sum, n


def _aux_from_stats(counts, prob_sum, n, moe: MoEConfig) -> jax.Array:
    """GShard/switch load-balance loss: E * sum_e f_e * P_e (global stats,
    so sharded and unsharded paths agree exactly)."""
    f = counts / jnp.maximum(jnp.sum(counts), 1.0)
    p = prob_sum / jnp.maximum(n, 1.0)
    return moe.n_experts * jnp.sum(f * p)


def _aux_loss(topi: jax.Array, probs: jax.Array, moe: MoEConfig) -> jax.Array:
    return _aux_from_stats(*_aux_stats(topi, probs, moe), moe)


def _dispatch(x_flat, topi, topv, capacity: int, n_experts: int):
    """Scatter tokens into per-expert capacity buffers.

    Returns (buf [E, C, D], entry_expert [T*k], entry_pos [T*k], keep [T*k]).
    Position-in-expert comes from an exclusive cumsum over the [T*k, E]
    assignment mask (f32 accumulation is exact for counts < 2^24).
    """
    t, k = topi.shape
    d = x_flat.shape[-1]
    e_flat = topi.reshape(-1)  # [T*k] routing entries in token-major order
    onehot = jax.nn.one_hot(e_flat, n_experts, dtype=jnp.float32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1).astype(jnp.int32)
    keep = pos < capacity
    slot = jnp.where(keep, e_flat * capacity + pos, n_experts * capacity)
    tokens_rep = jnp.repeat(x_flat, k, axis=0)  # [T*k, D]
    buf = jnp.zeros((n_experts * capacity + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(tokens_rep)
    return buf[:-1].reshape(n_experts, capacity, d), e_flat, pos, keep


def _combine(y_buf, e_flat, pos, keep, topv, t: int, capacity: int):
    """Gather expert outputs back to tokens and mix with router weights."""
    k = topv.shape[-1]
    d = y_buf.shape[-1]
    slot = jnp.where(keep, e_flat * capacity + pos, 0)
    gathered = y_buf.reshape(-1, d)[slot]  # [T*k, D]
    w = (topv.reshape(-1) * keep).astype(gathered.dtype)
    return jnp.sum((gathered * w[:, None]).reshape(t, k, d), axis=1)


def _expert_ffn(buf, w_gate, w_up, w_down):
    """buf: [E_local, C, D]; weights: [E_local, ...]."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(buf.dtype))


def _capacity(t: int, moe: MoEConfig) -> int:
    """Statistical capacity for large token counts; dropless floor for small
    ones (decode batches route few tokens — a collision on one expert must
    not drop, or decode diverges from prefill)."""
    statistical = math.ceil(t * moe.top_k / moe.n_experts * moe.capacity_factor)
    return max(1, statistical, min(t, 128))


def _moe_local(params, x, moe: MoEConfig):
    """Single-shard routed-experts pass. x: [b, s, d] (local)."""
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    topi, topv, probs = _route(x_flat, params["router"], moe)
    cap = _capacity(x_flat.shape[0], moe)
    buf, e_flat, pos, keep = _dispatch(x_flat, topi, topv, cap, moe.n_experts)
    y_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
    y = _combine(y_buf, e_flat, pos, keep, topv, x_flat.shape[0], cap)
    return y.reshape(b, s, d), _aux_loss(topi, probs, moe)


def _moe_ep_shard(params, x, moe: MoEConfig, model_axis: str, all_axes):
    """Expert-parallel pass inside shard_map; x is the LOCAL token shard.

    all-to-all #1: [E, C, D] -> [E/P, P*C, D] (experts home);
    all-to-all #2: adjoint, results back to token owners.
    """
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    topi, topv, probs = _route(x_flat, params["router"], moe)
    cap = _capacity(x_flat.shape[0], moe)
    buf, e_flat, pos, keep = _dispatch(x_flat, topi, topv, cap, moe.n_experts)
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1, tiled=True)
    y_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
    y_buf = jax.lax.all_to_all(y_buf, model_axis, split_axis=1, concat_axis=0, tiled=True)
    y = _combine(y_buf, e_flat, pos, keep, topv, x_flat.shape[0], cap)
    # aux loss from GLOBAL routing statistics (psum of per-shard counts),
    # so it equals the single-shard computation exactly
    counts, prob_sum, n = _aux_stats(topi, probs, moe)
    counts = jax.lax.psum(counts, all_axes)
    prob_sum = jax.lax.psum(prob_sum, all_axes)
    n = jax.lax.psum(n, all_axes)
    aux = _aux_from_stats(counts, prob_sum, n, moe)
    return y.reshape(b, s, d), aux


def moe_apply(
    params: dict,
    x: jax.Array,
    moe: MoEConfig,
    policy: ParallelPolicy = LOCAL,
) -> Tuple[jax.Array, jax.Array]:
    """Routed experts (+ shared experts). x: [b, s, d] global.

    Returns (y, aux_loss). Distributed path requires s % P == 0; decode
    (s == 1) and smoke tests use the local path under plain pjit.
    """
    b, s, d = x.shape
    p_size = policy.model_size()
    use_a2a = (
        policy.distributed and policy.moe_a2a and s % p_size == 0 and p_size > 1
        and moe.n_experts % p_size == 0
    )
    if use_a2a:
        mesh = policy.mesh
        dp, mx = policy.dp_axes, policy.model_axis
        x = policy.shard(x, dp, mx, None)
        specs = {
            "router": P(),
            "w_gate": P(mx, None, None),
            "w_up": P(mx, None, None),
            "w_down": P(mx, None, None),
        }
        routed = {k: params[k] for k in specs}
        all_axes = tuple(a for grp in (dp, (mx,)) for a in (grp if isinstance(grp, tuple) else (grp,)))
        y, aux = compat.shard_map(
            lambda pr, xx: _moe_ep_shard(pr, xx, moe, mx, all_axes),
            mesh,
            (specs, P(dp, mx, None)),
            (P(dp, mx, None), P()),
        )(routed, x)
        y = policy.shard_act(y)
    else:
        y, aux = _moe_local(params, x, moe)

    if "shared" in params:
        sh = params["shared"]
        y = y + layers.glu_mlp(x, sh["w_gate"], sh["w_up"], sh["w_down"], act="swiglu")
    return y, aux * moe.aux_coef
