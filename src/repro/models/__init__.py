"""LM architecture substrate (dense / MoE / SSM / hybrid / enc-dec)."""
from repro.models.policy import LOCAL, ParallelPolicy  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    init_cache,
    init_lm_params,
    lm_decode_step,
    lm_hidden,
    lm_loss,
    lm_prefill,
    param_specs,
)
from repro.models.whisper import (  # noqa: F401
    init_whisper_cache,
    init_whisper_params,
    whisper_decode_step,
    whisper_loss,
    whisper_param_specs,
    whisper_prefill,
)
