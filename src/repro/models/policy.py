"""Parallelism policy: how a model maps onto the production mesh.

All model code is written as local math over global arrays; distribution is
expressed through (a) parameter PartitionSpecs and (b) activation sharding
constraints issued via ``ParallelPolicy.shard``. On a 1-device CPU (smoke
tests) the policy is inert; under pjit on the production mesh the same code
lowers to TP+DP(+EP/SP) SPMD. Explicit shard_map regions (MoE all-to-all,
Ulysses attention) consult the policy for axis names.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelPolicy:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # Megatron-style sequence sharding of residual activations over the
    # model axis (reduces per-device activation bytes; adds AG/RS pairs).
    seq_shard: bool = False
    # MoE expert dispatch through the explicit shard_map all-to-all
    # (the paper's repartition primitive); False = dense local routing.
    moe_a2a: bool = True
    # Remat (activation checkpointing) for the layer scan.
    remat: bool = True
    # Remat policy: None = recompute everything; "dots" = save matmul
    # outputs (jax.checkpoint_policies.dots_saveable) so backward does not
    # re-execute the all-gathers/all-reduces feeding them (collective-term
    # optimization, trades peak memory).
    remat_policy: Optional[str] = None
    # Route hot ops through Pallas kernels (TPU runtime only).
    use_pallas: bool = False
    # Unroll the layer loop at decode time. Keeps the (huge) KV prefix out
    # of while-loop carries so per-layer dtype converts stay transient —
    # decode HLO is small, so the unrolled program is still compact.
    unroll_decode: bool = False
    # int8 KV-cache prefix with per-token/head scales (split caches only):
    # halves decode HBM residency at ~1e-2 relative logit error.
    kv_quant: bool = False

    @property
    def distributed(self) -> bool:
        return self.mesh is not None

    def model_size(self) -> int:
        if not self.mesh:
            return 1
        return self.mesh.shape[self.model_axis]

    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    # -- activation constraints ------------------------------------------
    def shard(self, x: jax.Array, *spec) -> jax.Array:
        """with_sharding_constraint if a mesh is attached, else identity.

        Axes whose mesh size does not divide the tensor dim are dropped
        (e.g. batch 1 at long_500k, kv_heads 2 < 16) — the cell still
        lowers, just without sharding that dim.
        """
        if self.mesh is None:
            return x
        fixed = []
        for dim, ax in enumerate(spec):
            if ax is None or dim >= x.ndim:
                fixed.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            fixed.append(ax if x.shape[dim] % size == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed))
        )

    def act_spec(self, seq_dim_shardable: bool = True):
        """Default residual-stream spec for [batch, seq, d]."""
        if self.seq_shard and seq_dim_shardable:
            return (self.dp_axes, self.model_axis, None)
        return (self.dp_axes, None, None)

    def shard_act(self, x: jax.Array, seq_dim_shardable: bool = True) -> jax.Array:
        return self.shard(x, *self.act_spec(seq_dim_shardable))


LOCAL = ParallelPolicy(mesh=None)
