"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a STUB: inputs are precomputed
frame embeddings [b, frames, d_model] (what the two conv layers would emit).
Encoder: bidirectional self-attention + GELU MLP, sinusoidal positions.
Decoder: causal self-attention + cross-attention + GELU MLP. LayerNorms with
bias throughout (whisper convention); no RoPE — sinusoidal positions are
used for the decoder too (deviation from whisper's learned positions, noted
in DESIGN.md: length-free positions let the assigned 4k/32k shape cells run
beyond whisper's 448-token trained horizon).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_lib
from repro.models import layers
from repro.models.policy import ParallelPolicy, LOCAL


def _sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_ln(d):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _ln(x, p, eps=1e-5):
    return layers.layer_norm(x, p["w"], p["b"], eps=eps)


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "attn": attn_lib.init_attn_params(ks[0], cfg),
        "mlp": _init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff),
        "ln1": _init_ln(cfg.d_model),
        "ln2": _init_ln(cfg.d_model),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "self_attn": attn_lib.init_attn_params(ks[0], cfg),
        "cross_attn": attn_lib.init_attn_params(ks[1], cfg),
        "mlp": _init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff),
        "ln1": _init_ln(cfg.d_model),
        "ln2": _init_ln(cfg.d_model),
        "ln3": _init_ln(cfg.d_model),
    }


def _init_gelu_mlp(key, d, f):
    ks = jax.random.split(key, 2)
    return {
        "w1": jax.random.normal(ks[0], (d, f), jnp.float32) * d ** -0.5,
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": jax.random.normal(ks[1], (f, d), jnp.float32) * f ** -0.5,
        "b2": jnp.zeros((d,), jnp.float32),
    }


def init_whisper_params(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    d, v = cfg.d_model, cfg.vocab
    return {
        "enc": {
            "layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
            "final_ln": _init_ln(d),
        },
        "dec": {
            "embed": jax.random.normal(ks[2], (v, d), jnp.float32) * d ** -0.5,
            "layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
            "final_ln": _init_ln(d),
            "lm_head": jax.random.normal(ks[3], (d, v), jnp.float32) * d ** -0.5,
        },
    }


def whisper_param_specs(cfg, policy: ParallelPolicy) -> dict:
    mx = policy.model_axis
    a = {"wq": P(None, None, mx), "wk": P(None, None, mx), "wv": P(None, None, mx), "wo": P(None, mx, None)}
    if cfg.qkv_bias:
        a.update({"bq": P(None, mx), "bk": P(None, mx), "bv": P(None, mx)})
    mlp = {"w1": P(None, None, mx), "b1": P(None, mx), "w2": P(None, mx, None), "b2": P()}
    ln = {"w": P(), "b": P()}
    enc_layer = {"attn": a, "mlp": mlp, "ln1": ln, "ln2": ln}
    dec_layer = {"self_attn": a, "cross_attn": a, "mlp": mlp, "ln1": ln, "ln2": ln, "ln3": ln}
    return {
        "enc": {"layers": enc_layer, "final_ln": ln},
        "dec": {
            "embed": P(None, None),  # 51865 not divisible by 16 -> replicated
            "layers": dec_layer,
            "final_ln": ln,
            "lm_head": P(None, None),
        },
    }


def _cross_attention(p, x, enc_k, enc_v, cfg, policy):
    """q from decoder stream; k/v precomputed from encoder output."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = x @ p["wq"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd).swapaxes(1, 2)
    from repro.kernels.flash_attention import flash_attention
    o = flash_attention(q, enc_k, enc_v, causal=False, use_pallas=policy.use_pallas)
    o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * hd)
    return o @ p["wo"].astype(x.dtype)


def _enc_kv(p, enc_out, cfg):
    b, f, _ = enc_out.shape
    hd = cfg.head_dim_
    k = enc_out @ p["wk"].astype(enc_out.dtype)
    v = enc_out @ p["wv"].astype(enc_out.dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    k = k.reshape(b, f, cfg.kv_heads, hd).swapaxes(1, 2)
    v = v.reshape(b, f, cfg.kv_heads, hd).swapaxes(1, 2)
    return k, v


def encode(params, frames, cfg, policy: ParallelPolicy = LOCAL):
    """frames: [b, F, d] (stub frontend output) -> encoder states."""
    x = frames.astype(cfg.activation_dtype)
    f = frames.shape[1]
    x = x + _sinusoid(jnp.arange(f), cfg.d_model).astype(x.dtype)[None]
    x = policy.shard_act(x)

    def body(x, lp):
        h = _ln(x, lp["ln1"])
        x = x + attn_lib.attn_forward(lp["attn"], h, cfg, policy, causal=False)
        h = _ln(x, lp["ln2"])
        x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"])
        return policy.shard_act(x), None

    x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
    return _ln(x, params["enc"]["final_ln"])


def decode_train(params, tokens, enc_out, cfg, policy: ParallelPolicy = LOCAL):
    """Teacher-forced decoder pass -> final hidden states."""
    b, s = tokens.shape
    dec = params["dec"]
    x = layers.embed(dec["embed"], tokens).astype(cfg.activation_dtype)
    x = x + _sinusoid(jnp.arange(s), cfg.d_model).astype(x.dtype)[None]
    x = policy.shard_act(x)

    def body(x, lp):
        h = _ln(x, lp["ln1"])
        x = x + attn_lib.attn_forward(lp["self_attn"], h, cfg, policy, causal=True)
        h = _ln(x, lp["ln2"])
        ek, ev = _enc_kv(lp["cross_attn"], enc_out, cfg)
        x = x + _cross_attention(lp["cross_attn"], h, ek, ev, cfg, policy)
        h = _ln(x, lp["ln3"])
        x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"])
        return policy.shard_act(x), None

    body = jax.checkpoint(body) if policy.remat else body
    x, _ = jax.lax.scan(body, x, dec["layers"])
    return _ln(x, dec["final_ln"])


def whisper_loss(params, batch, cfg, policy: ParallelPolicy = LOCAL):
    enc_out = encode(params, batch["frames"], cfg, policy)
    h = decode_train(params, batch["tokens"], enc_out, cfg, policy)
    xent = layers.chunked_cross_entropy(
        h, params["dec"]["lm_head"], batch["targets"],
        policy=policy if policy.distributed else None,
    )
    return xent, {"xent": xent}


# -- serving ------------------------------------------------------------------

def init_whisper_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    hd = cfg.head_dim_
    f = cfg.encoder.frames
    n = cfg.n_layers
    return {
        "self": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(),
            attn_lib.init_kv_cache(cfg, batch, max_len, dtype),
        ),
        "cross_k": jnp.zeros((n, batch, cfg.kv_heads, f, hd), dtype),
        "cross_v": jnp.zeros((n, batch, cfg.kv_heads, f, hd), dtype),
    }


def whisper_prefill(params, tokens, frames, cfg, policy: ParallelPolicy = LOCAL, max_len=None):
    """Encode audio + teacher-force the prompt; emit decode cache."""
    b, s = tokens.shape
    max_len = max_len or s
    enc_out = encode(params, frames, cfg, policy)
    dec = params["dec"]
    x = layers.embed(dec["embed"], tokens).astype(cfg.activation_dtype)
    x = x + _sinusoid(jnp.arange(s), cfg.d_model).astype(x.dtype)[None]

    def body(x, lp):
        h = _ln(x, lp["ln1"])
        positions = jnp.arange(s)
        q, k, v = attn_lib._project_qkv(lp["self_attn"], h, cfg, positions)
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), causal=True)
        o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim_)
        x = x + o @ lp["self_attn"]["wo"].astype(x.dtype)
        h = _ln(x, lp["ln2"])
        ek, ev = _enc_kv(lp["cross_attn"], enc_out, cfg)
        x = x + _cross_attention(lp["cross_attn"], h, ek, ev, cfg, policy)
        h = _ln(x, lp["ln3"])
        x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"])
        pad = max_len - s
        kc = jnp.pad(k.swapaxes(1, 2), ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(v.swapaxes(1, 2), ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x, {"k": kc.astype(jnp.bfloat16), "v": vc.astype(jnp.bfloat16), "ek": ek.astype(jnp.bfloat16), "ev": ev.astype(jnp.bfloat16)}

    x, caches = jax.lax.scan(body, x, dec["layers"])
    h = _ln(x, dec["final_ln"])
    logits = layers.logits_last(h[:, -1], dec["lm_head"])
    cache = {
        "self": {"k": caches["k"], "v": caches["v"]},
        "cross_k": caches["ek"],
        "cross_v": caches["ev"],
    }
    return logits, cache


def whisper_decode_step(params, token, cache, index, cfg, policy: ParallelPolicy = LOCAL):
    """One decoder token step against self cache + static cross cache."""
    dec = params["dec"]
    b = token.shape[0]
    x = layers.embed(dec["embed"], token).astype(cfg.activation_dtype)
    pos = jnp.full((1,), index, jnp.int32)
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)[None]

    def body(x, inp):
        lp, sc, ek, ev = inp
        h = _ln(x, lp["ln1"])
        y, new_sc = attn_lib.attn_decode(lp["self_attn"], h, sc, index, cfg, policy)
        x = x + y
        h = _ln(x, lp["ln2"])
        x = x + _cross_attention(lp["cross_attn"], h, ek.astype(x.dtype), ev.astype(x.dtype), cfg, policy)
        h = _ln(x, lp["ln3"])
        x = x + layers.gelu_mlp(h, lp["mlp"]["w1"], lp["mlp"]["b1"], lp["mlp"]["w2"], lp["mlp"]["b2"])
        return x, new_sc

    x, new_self = jax.lax.scan(
        body, x, (dec["layers"], cache["self"], cache["cross_k"], cache["cross_v"])
    )
    h = _ln(x, dec["final_ln"])
    logits = layers.logits_last(h[:, 0], dec["lm_head"])
    return logits, {"self": new_self, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
