"""Attention variants: GQA/MQA/MHA, sliding-window, MLA, with KV caches.

Layout conventions:
  * residual stream x: [b, s, d]
  * heads internally:  [b, h, s, hd] (kernel layout)
  * KV cache:          {"k": [b, kvh, s_max, hd], "v": ...} + scalar length
  * MLA cache:         {"ckv": [b, s_max, kv_lora], "kr": [b, s_max, dh_rope]}
    (the compressed-latent cache — 576 floats/token instead of
    2*h*hd = 4096 for an equivalent GQA cache; this is the decode-memory
    optimization exploited in §Perf.)

Prefill/train go through kernels.flash_attention (chunked online-softmax on
XLA, Pallas kernel on TPU). Decode is a masked single-query attention over
the cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_chunked, flash_attention
from repro.models import layers
from repro.models.policy import ParallelPolicy, LOCAL


# ---------------------------------------------------------------------------
# Standard multi-head attention with GQA and optional sliding window.
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg) -> dict:
    d = cfg.d_model
    hd = cfg.head_dim_
    h, kvh = cfg.n_heads, cfg.kv_heads
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), jnp.float32) * std,
        "wk": jax.random.normal(ks[1], (d, kvh * hd), jnp.float32) * std,
        "wv": jax.random.normal(ks[2], (d, kvh * hd), jnp.float32) * std,
        "wo": jax.random.normal(ks[3], (h * hd, d), jnp.float32) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kvh * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kvh * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.kv_heads, hd)
    v = v.reshape(b, s, cfg.kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    if cfg.rope_fraction > 0:
        q = layers.apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        k = layers.apply_rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    return q, k, v


def _pad_heads(q, k, v, p_size: int):
    """Zero-pad the head dim to a multiple of the model-axis size.

    When n_heads %% P != 0 (qwen 40 on a 16-way axis, recurrentgemma 10),
    the column-sharded qkv projections put shard boundaries INSIDE heads and
    the SPMD partitioner emits involuntary all-reduces of attention logits
    (measured 190+ GB/step wire — EXPERIMENTS §Perf hillclimb 3). Padded
    heads have zero q/k/v, so their (sliced-away) outputs never contribute:
    the transform is exact. kv heads are padded alongside only in the MHA
    case (group structure must stay integral). Layout: [b, h, s, d].
    """
    h, kvh = q.shape[1], k.shape[1]
    hp = -(-h // p_size) * p_size
    if hp == h:
        return q, k, v, h
    q = jnp.pad(q, ((0, 0), (0, hp - h), (0, 0), (0, 0)))
    if kvh == h:  # MHA: pad kv identically so group size stays 1
        k = jnp.pad(k, ((0, 0), (0, hp - h), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, hp - h), (0, 0), (0, 0)))
    elif hp % kvh:
        raise ValueError(f"cannot pad heads {h}->{hp} with kv_heads {kvh}")
    return q, k, v, h


def attn_forward(
    p: dict,
    x: jax.Array,
    cfg,
    policy: ParallelPolicy = LOCAL,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill without cache)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    # kernel layout [b, h, s, hd]; heads sharded over the model axis
    # (zero-padded up to a multiple of the axis when needed).
    q, k, v = q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2)
    if policy.distributed:
        q, k, v, h_real = _pad_heads(q, k, v, policy.model_size())
    else:
        h_real = cfg.n_heads
    q = policy.shard(q, policy.dp_axes, policy.model_axis, None, None)
    k = policy.shard(k, policy.dp_axes, policy.model_axis, None, None)
    v = policy.shard(v, policy.dp_axes, policy.model_axis, None, None)
    if cfg.window is not None and s > cfg.window:
        o = _windowed_attention(q, k, v, cfg.window)
    else:
        o = flash_attention(
            q, k, v, causal=causal, use_pallas=policy.use_pallas,
            chunk_k=min(1024, s),
        )
    o = o[:, :h_real].swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim_)
    return o @ p["wo"].astype(x.dtype)


def _windowed_attention(q, k, v, window: int):
    """Sliding-window causal attention (recurrentgemma local layers).

    Memory O(s * window): queries are processed in window-sized blocks, each
    attending to its own and the previous key block (positions within the
    window), never the full S x S matrix.
    """
    b, h, s, hd = q.shape
    kvh = k.shape[1]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=1)
        v = jnp.repeat(v, h // kvh, axis=1)
    s_real = s
    pad = (-s) % window
    if pad:  # end-pad: padded keys are in every real query's future (masked)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s = s + pad
    nb = s // window
    scale = hd ** -0.5
    qb = q.reshape(b, h, nb, window, hd)
    kb = k.reshape(b, h, nb, window, hd)
    vb = v.reshape(b, h, nb, window, hd)
    # previous block of keys/values (zeros for block 0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], axis=2)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    kcat = jnp.concatenate([kprev, kb], axis=3)  # [b,h,nb,2w,hd]
    vcat = jnp.concatenate([vprev, vb], axis=3)
    logits = jnp.einsum("bhnqd,bhnkd->bhnqk", qb, kcat).astype(jnp.float32) * scale
    qpos = jnp.arange(window)[:, None] + window  # position within the 2w slab
    kpos = jnp.arange(2 * window)[None, :]
    block = jnp.arange(nb)[:, None, None]
    valid = (kpos <= qpos) & (kpos > qpos - window)
    # block 0 has no previous keys
    valid0 = valid & (kpos >= window)
    mask = jnp.where(block == 0, valid0[None], valid[None])
    logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhnqk,bhnkd->bhnqd", w, vcat)
    return o.reshape(b, h, s, hd)[:, :, :s_real]


# -- decode -----------------------------------------------------------------

TAIL_LEN = 64  # split-cache tail ring size (flushed to prefix every TAIL_LEN)


def init_kv_cache(
    cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *, split=False, quant=False
) -> dict:
    """Plain cache: one [b, kvh, S, hd] buffer per k/v.

    split=True: prefix/tail layout for seq-sharded caches — the prefix is
    READ-ONLY inside a decode step (so it can be sharded over the model axis
    without dynamic-update-slice crossing shards, which forces XLA to
    replicate the tensor), and appends go to a small replicated tail ring.
    The serve engine flushes the tail into the prefix every TAIL_LEN steps.

    quant=True (requires split): the prefix is stored int8 with per-token,
    per-head max-abs scales (k_scale/v_scale, bf16) — halves decode HBM
    residency (qwen-32B decode_32k: 21.5 -> 10.9 GiB/device, fitting a
    single v5e pod). Scales fold into the logits / softmax weights, so the
    attention dots still consume narrow dtypes.
    """
    hd = cfg.head_dim_
    kvh = cfg.kv_heads
    length = max_len if cfg.window is None else min(max_len, cfg.window)
    kv_dtype = jnp.int8 if (quant and split and cfg.window is None) else dtype
    cache = {
        "k": jnp.zeros((batch, kvh, length, hd), kv_dtype),
        "v": jnp.zeros((batch, kvh, length, hd), kv_dtype),
    }
    if kv_dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((batch, kvh, length), jnp.bfloat16)
        cache["v_scale"] = jnp.zeros((batch, kvh, length), jnp.bfloat16)
    if split and cfg.window is None:
        cache["tk"] = jnp.zeros((batch, kvh, TAIL_LEN, hd), dtype)
        cache["tv"] = jnp.zeros((batch, kvh, TAIL_LEN, hd), dtype)
    return cache


def quantize_kv(x: jax.Array):
    """x: [b, kvh, s, hd] -> (int8 values, bf16 per-(token,head) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def attn_decode(
    p: dict,
    x: jax.Array,          # [b, 1, d] current token's hidden state
    cache: dict,
    index: jax.Array,      # scalar int32: number of tokens already in cache
    cfg,
    policy: ParallelPolicy = LOCAL,
):
    """One decode step: append to cache, attend over valid prefix."""
    b = x.shape[0]
    hd = cfg.head_dim_
    positions = jnp.full((b, 1), index, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    if "tk" in cache:  # split prefix/tail cache (seq-sharded prefix)
        return _attn_decode_split(p, x, q, k, v, cache, index, cfg, policy)
    s_max = cache["k"].shape[2]
    slot = index % s_max if cfg.window is not None else index
    k_new = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.swapaxes(1, 2).astype(cache["k"].dtype), slot, axis=2
    )
    v_new = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.swapaxes(1, 2).astype(cache["v"].dtype), slot, axis=2
    )
    kpos = jnp.arange(s_max)
    if cfg.window is not None:
        valid = (kpos[None, :] <= slot) | (index >= s_max)
    else:
        valid = kpos[None, :] <= index
    o = decode_attention(
        q.swapaxes(1, 2), k_new, v_new, valid, policy=policy
    )  # [b, h, 1, hd]
    o = o.swapaxes(1, 2).reshape(b, 1, cfg.n_heads * hd)
    out = o @ p["wo"].astype(x.dtype)
    return out, {"k": k_new, "v": v_new}


def _attn_decode_split(p, x, q, k, v, cache, index, cfg, policy):
    """Decode against a read-only prefix + small tail ring.

    The prefix is never written (alias-friendly, shardable along seq); the
    new token's k/v go into the tail at slot = index - prefix_len. The
    softmax is combined across the two segments flash-decode style: the
    reductions over the sharded prefix seq dim become psums under SPMD.
    """
    b = x.shape[0]
    hd = cfg.head_dim_
    prefix_len = cache["k"].shape[2]
    slot = index - prefix_len
    tk = jax.lax.dynamic_update_slice_in_dim(
        cache["tk"], k.swapaxes(1, 2).astype(cache["tk"].dtype), slot, axis=2
    )
    tv = jax.lax.dynamic_update_slice_in_dim(
        cache["tv"], v.swapaxes(1, 2).astype(cache["tv"].dtype), slot, axis=2
    )
    qh = q.swapaxes(1, 2)  # [b, h, 1, hd]
    kvh = cfg.kv_heads
    group = cfg.n_heads // kvh
    # Keep cache operands in their storage dtype; accumulate in f32 via
    # preferred_element_type — casting the cache would materialize a full
    # f32 copy of the (huge) prefix.
    quant = "k_scale" in cache
    kv_compute = jnp.bfloat16 if quant else cache["k"].dtype
    qg = qh.reshape(b, kvh, group, hd).astype(kv_compute)
    scale = hd ** -0.5
    f32 = jnp.float32
    kp = cache["k"].astype(kv_compute) if quant else cache["k"]
    vp = cache["v"].astype(kv_compute) if quant else cache["v"]
    lp = jnp.einsum("bkgd,bksd->bkgs", qg, kp, preferred_element_type=f32) * scale
    if quant:  # fold dequant scales into logits / softmax weights
        lp = lp * cache["k_scale"].astype(f32)[:, :, None, :]
    lt = jnp.einsum("bkgd,bktd->bkgt", qg.astype(tk.dtype), tk, preferred_element_type=f32) * scale
    t_valid = jnp.arange(tk.shape[2])[None, :] <= slot
    lt = jnp.where(t_valid[:, None, None, :], lt, -1e30)
    m = jnp.maximum(
        jnp.max(lp, axis=-1, keepdims=True), jnp.max(lt, axis=-1, keepdims=True)
    )
    wp = jnp.exp(lp - m)
    wt = jnp.exp(lt - m)
    denom = jnp.sum(wp, axis=-1, keepdims=True) + jnp.sum(wt, axis=-1, keepdims=True)
    if quant:
        wp = wp * cache["v_scale"].astype(f32)[:, :, None, :]
    o = jnp.einsum("bkgs,bksd->bkgd", wp.astype(kv_compute), vp, preferred_element_type=f32)
    o = o + jnp.einsum("bkgt,bktd->bkgd", wt.astype(tv.dtype), tv, preferred_element_type=f32)
    o = (o / denom).reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    out = o @ p["wo"].astype(x.dtype)
    new_cache = {"k": cache["k"], "v": cache["v"], "tk": tk, "tv": tv}
    if quant:
        new_cache["k_scale"] = cache["k_scale"]
        new_cache["v_scale"] = cache["v_scale"]
    return out, new_cache


def flush_tail(cache: dict, prefix_valid: int):
    """Merge the tail ring back into the prefix (engine-side, amortized).

    Writes tail entries at positions [prefix_valid, prefix_valid+T) via a
    static concat-roll (the prefix buffer must have room)."""
    t = cache["tk"].shape[2]
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], cache["tk"], prefix_valid, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], cache["tv"], prefix_valid, axis=2)
    return {
        "k": k, "v": v,
        "tk": jnp.zeros_like(cache["tk"]),
        "tv": jnp.zeros_like(cache["tv"]),
    }


def decode_attention(q, k, v, valid, *, policy: ParallelPolicy = LOCAL):
    """q: [b, h, 1, hd]; k/v: [b, kvh, s, hd]; valid: [b or 1, s] bool."""
    b, h, _, hd = q.shape
    kvh = k.shape[1]
    group = h // kvh
    qg = q.reshape(b, kvh, group, hd).astype(k.dtype)
    logits = jnp.einsum(
        "bkgd,bksd->bkgs", qg, k, preferred_element_type=jnp.float32
    ) * (hd ** -0.5)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", w.astype(v.dtype), v, preferred_element_type=jnp.float32)
    return o.reshape(b, h, 1, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2). Decoupled RoPE: per-head
# no-pe dims attend against latent up-projections; a shared rope head rides
# alongside. Cache = compressed latent + shared rope key.
# ---------------------------------------------------------------------------

def init_mla_params(key, cfg) -> dict:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * (m.dh_nope + m.dh_rope)), jnp.float32) * std,
        "w_dkv": jax.random.normal(ks[1], (d, m.kv_lora + m.dh_rope), jnp.float32) * std,
        "kv_norm": jnp.ones((m.kv_lora,), jnp.float32),
        "k_up": jax.random.normal(ks[2], (m.kv_lora, h * m.dh_nope), jnp.float32) * (m.kv_lora ** -0.5),
        "v_up": jax.random.normal(ks[3], (m.kv_lora, h * m.dh_v), jnp.float32) * (m.kv_lora ** -0.5),
        "wo": jax.random.normal(ks[4], (h * m.dh_v, d), jnp.float32) * std,
    }


def _mla_qkr(p, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, m.dh_nope + m.dh_rope)
    q_nope, q_rope = q[..., : m.dh_nope], q[..., m.dh_nope:]
    q_rope = layers.apply_rope(q_rope, positions, theta=cfg.rope_theta)
    dkv = x @ p["w_dkv"].astype(x.dtype)
    ckv, k_rope = dkv[..., : m.kv_lora], dkv[..., m.kv_lora:]
    ckv = layers.rms_norm(ckv, p["kv_norm"])
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, theta=cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_forward(p, x, cfg, policy: ParallelPolicy = LOCAL, *, positions=None):
    """Full-sequence MLA (train / prefill): materialize per-head k/v."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope, ckv, k_rope = _mla_qkr(p, x, cfg, positions)
    k_nope = (ckv @ p["k_up"].astype(x.dtype)).reshape(b, s, h, m.dh_nope)
    v = (ckv @ p["v_up"].astype(x.dtype)).reshape(b, s, h, m.dh_v)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, m.dh_rope))], axis=-1)
    scale = (m.dh_nope + m.dh_rope) ** -0.5
    # pad v head dim up to q/k head dim for the shared kernel, slice after
    o = flash_attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2),
        jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q.shape[-1] - m.dh_v))).swapaxes(1, 2),
        causal=True, scale=scale, use_pallas=policy.use_pallas, chunk_k=min(1024, s),
    ).swapaxes(1, 2)[..., : m.dh_v]
    return o.reshape(b, s, h * m.dh_v) @ p["wo"].astype(x.dtype)


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *, split=False) -> dict:
    m = cfg.mla
    cache = {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
        "kr": jnp.zeros((batch, max_len, m.dh_rope), dtype),
    }
    if split:
        cache["tckv"] = jnp.zeros((batch, TAIL_LEN, m.kv_lora), dtype)
        cache["tkr"] = jnp.zeros((batch, TAIL_LEN, m.dh_rope), dtype)
    return cache


def mla_decode(p, x, cache, index, cfg, policy: ParallelPolicy = LOCAL):
    """Absorbed-projection decode: attention runs in the latent space, so the
    per-token cache cost is kv_lora + dh_rope (576) regardless of heads.
    Split caches keep the prefix read-only (seq-shardable) and append to a
    small tail ring, combining the two segments flash-decode style."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((b, 1), index, jnp.int32)
    q_nope, q_rope, ckv, k_rope = _mla_qkr(p, x, cfg, positions)
    scale = (m.dh_nope + m.dh_rope) ** -0.5
    # Absorb k_up into q: q_lat[b,h,L] = q_nope[b,h,dn] @ k_up[L, h, dn]^T
    k_up = p["k_up"].reshape(m.kv_lora, h, m.dh_nope)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32), k_up.astype(jnp.float32))
    qr = q_rope[:, 0].astype(jnp.float32)

    f32 = jnp.float32

    def seg_logits(ckv_seg, kr_seg):
        lg = jnp.einsum("bhl,bsl->bhs", q_lat.astype(ckv_seg.dtype), ckv_seg, preferred_element_type=f32)
        lg += jnp.einsum("bhr,bsr->bhs", qr.astype(kr_seg.dtype), kr_seg, preferred_element_type=f32)
        return lg * scale

    if "tckv" in cache:
        prefix_len = cache["ckv"].shape[1]
        slot = index - prefix_len
        tckv = jax.lax.dynamic_update_slice_in_dim(
            cache["tckv"], ckv.astype(cache["tckv"].dtype), slot, axis=1
        )
        tkr = jax.lax.dynamic_update_slice_in_dim(
            cache["tkr"], k_rope.astype(cache["tkr"].dtype), slot, axis=1
        )
        lp = seg_logits(cache["ckv"], cache["kr"])
        lt = seg_logits(tckv, tkr)
        t_valid = jnp.arange(tckv.shape[1])[None, :] <= slot
        lt = jnp.where(t_valid[:, None, :], lt, -1e30)
        mx = jnp.maximum(jnp.max(lp, -1, keepdims=True), jnp.max(lt, -1, keepdims=True))
        wp, wt = jnp.exp(lp - mx), jnp.exp(lt - mx)
        denom = jnp.sum(wp, -1, keepdims=True) + jnp.sum(wt, -1, keepdims=True)
        o_lat = jnp.einsum("bhs,bsl->bhl", wp.astype(cache["ckv"].dtype), cache["ckv"], preferred_element_type=f32)
        o_lat += jnp.einsum("bht,btl->bhl", wt.astype(tckv.dtype), tckv, preferred_element_type=f32)
        o_lat = o_lat / denom
        new_cache = {"ckv": cache["ckv"], "kr": cache["kr"], "tckv": tckv, "tkr": tkr}
    else:
        ckv_new = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), index, axis=1
        )
        kr_new = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], k_rope.astype(cache["kr"].dtype), index, axis=1
        )
        logits = seg_logits(ckv_new, kr_new)
        valid = jnp.arange(cache["ckv"].shape[1])[None, :] <= index
        logits = jnp.where(valid[:, None, :], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhs,bsl->bhl", w, ckv_new.astype(jnp.float32))
        new_cache = {"ckv": ckv_new, "kr": kr_new}

    v_up = p["v_up"].reshape(m.kv_lora, h, m.dh_v)
    o = jnp.einsum("bhl,lhv->bhv", o_lat, v_up.astype(jnp.float32))
    o = o.reshape(b, 1, h * m.dh_v).astype(x.dtype)
    out = o @ p["wo"].astype(x.dtype)
    return out, new_cache
