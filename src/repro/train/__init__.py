from repro.train.optimizer import (  # noqa: F401
    AdamWConfig,
    adamw_update,
    init_opt_state,
    opt_state_specs,
    warmup_cosine,
    zero1_specs,
)
from repro.train.train_loop import make_train_step, shard_train_step  # noqa: F401
