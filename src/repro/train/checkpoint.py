"""Sharded, elastic, async checkpointing (no external deps).

Layout: <dir>/step_<N>/ with
  manifest.json        — treedef paths, global shapes/dtypes, shard index
  <leaf>.<shard>.npy   — np.save of each addressable shard + its slice

Properties needed at 1000+ nodes, kept here in single-process form with the
same interfaces:
  * each process saves only its ADDRESSABLE shards (no gather through one
    host) — shard filenames carry the global slice, so any process layout
    can write disjoint files;
  * atomic publish: write into step_N.tmp, fsync, os.rename -> readers never
    see partial checkpoints; a failed save leaves the previous step intact;
  * elastic restore: shards are reassembled to the global array and
    re-device_put with the NEW mesh/sharding — restarting on a different
    device count or pod count works (tested);
  * async save: snapshot to host (device_get) on the caller, file IO on a
    background thread so the train loop keeps stepping.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts) or "leaf"


def _slices_of(arr) -> list:
    """[(leaf_slice_tuple, np_shard), ...] for addressable shards."""
    if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
        out = []
        seen = set()
        for sh in arr.addressable_shards:
            idx = tuple(
                (s.start or 0, s.stop if s.stop is not None else dim)
                for s, dim in zip(sh.index, arr.shape)
            )
            if idx in seen:  # replicated: save once
                continue
            seen.add(idx)
            out.append((idx, np.asarray(sh.data)))
        return out
    a = np.asarray(arr)
    return [(tuple((0, d) for d in a.shape), a)]


def save(
    ckpt_dir: str,
    step: int,
    tree: Any,
    *,
    extra: Optional[dict] = None,
    async_save: bool = False,
    keep: int = 3,
):
    """Save a pytree checkpoint. Returns the (future) final directory."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    # Snapshot on the caller thread so async IO sees consistent data.
    snapshot = [(_leaf_name(p), _slices_of(jax.device_get(v))) for p, v in leaves]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")

    def _write():
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for name, shards in snapshot:
            entries = []
            for i, (idx, data) in enumerate(shards):
                fname = f"{name}.{i}.npy"
                np.save(os.path.join(tmp, fname), data)
                entries.append({"file": fname, "index": idx})
            global_shape = [max(e["index"][d][1] for e in entries) for d in range(len(entries[0]["index"]))] if entries[0]["index"] else []
            manifest["leaves"][name] = {
                "shape": global_shape,
                "dtype": str(shards[0][1].dtype),
                "shards": entries,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _cleanup(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return final, t
    _write()
    return final, None


def _cleanup(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str,
    abstract_tree: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
):
    """Restore into the structure of ``abstract_tree``.

    ``shardings`` (optional pytree of jax.sharding.Sharding) re-places the
    arrays on the CURRENT mesh — elastic restarts re-shard here.
    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (path, aleaf), shd in zip(leaves, shard_leaves):
        name = _leaf_name(path)
        ent = manifest["leaves"][name]
        arr = np.zeros(ent["shape"], dtype=np.dtype(ent["dtype"]))
        for srec in ent["shards"]:
            data = np.load(os.path.join(d, srec["file"]))
            sl = tuple(slice(a, b) for a, b in srec["index"])
            arr[sl] = data
        if list(arr.shape) != list(aleaf.shape):
            raise ValueError(f"{name}: ckpt shape {arr.shape} != expected {aleaf.shape}")
        if shd is not None:
            out.append(jax.device_put(arr.astype(aleaf.dtype), shd))
        else:
            out.append(jax.numpy.asarray(arr.astype(aleaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), step, manifest["extra"]
