"""Fault tolerance: supervised training with checkpoint/restart, injected
failures for testing, and a straggler watchdog.

At 1000+ nodes the failure model is: a worker dies mid-step (preemption or
hardware), the job controller restarts the step from the last published
checkpoint — possibly on a different device count (elastic). This module
implements that control loop in single-process form with the same state
machine; failures are injected via ``FaultInjector`` in tests, and elastic
restart is exercised by restoring onto a different mesh (see
tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax

from repro.train import checkpoint as ckpt_lib


class FaultInjector:
    """Raises at configured steps, once each (simulated node failures)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the running median."""

    threshold: float = 2.0
    history: List[float] = dataclasses.field(default_factory=list)
    flagged: List[tuple] = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        self.history.append(seconds)
        n = len(self.history)
        if n < 5:
            return False
        median = sorted(self.history)[n // 2]
        if seconds > self.threshold * median:
            self.flagged.append((step, seconds, median))
            return True
        return False


@dataclasses.dataclass
class SupervisorResult:
    final_step: int
    failures: int
    restores: int
    metrics_log: list
    straggler_steps: list


def run_supervised(
    *,
    init_state: Callable[[], Any],          # () -> state pytree
    train_step: Callable[[Any, Any], Any],  # (state, batch) -> (state, metrics)
    batch_iter,                              # iterator of batches (restartable by step)
    total_steps: int,
    ckpt_dir: str,
    save_every: int = 10,
    max_failures: int = 8,
    injector: Optional[FaultInjector] = None,
    shardings: Any = None,
    async_save: bool = False,
) -> SupervisorResult:
    """Train with checkpoint/restart. ``batch_iter(step)`` must return the
    batch for a given step so replays are deterministic after restore."""
    failures = 0
    restores = 0
    metrics_log = []
    watchdog = StragglerWatchdog()
    pending_save = None

    def _truncate_log(to_step: int):
        # a restore rewinds to ``to_step``; the rewound steps will be
        # re-executed and re-appended, so drop their old entries or the log
        # ends up with duplicate (step, metrics) pairs
        metrics_log[:] = [e for e in metrics_log if e[0] < to_step]

    latest = ckpt_lib.latest_step(ckpt_dir)
    if latest is not None:
        abstract = jax.eval_shape(init_state)
        state, step, _ = ckpt_lib.restore(ckpt_dir, abstract, shardings=shardings)
        step += 1
        restores += 1
        _truncate_log(step)
    else:
        state = init_state()
        step = 0

    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.time()
            state, metrics = train_step(state, batch_iter(step))
            jax.block_until_ready(jax.tree.leaves(state)[0])
            watchdog.observe(step, time.time() - t0)
            metrics_log.append((step, jax.tree.map(lambda m: float(m), metrics)))
            if step % save_every == 0 or step == total_steps - 1:
                if pending_save is not None:
                    pending_save.join()  # one in-flight async save at a time
                _, pending_save = ckpt_lib.save(
                    ckpt_dir, step, state, async_save=async_save
                )
            step += 1
        except Exception:  # noqa: BLE001 — any worker failure
            failures += 1
            if failures > max_failures:
                raise
            if pending_save is not None:
                pending_save.join()
                pending_save = None
            latest = ckpt_lib.latest_step(ckpt_dir)
            if latest is None:
                state = init_state()
                step = 0
            else:
                abstract = jax.eval_shape(init_state)
                state, ck_step, _ = ckpt_lib.restore(ckpt_dir, abstract, shardings=shardings)
                step = ck_step + 1
            _truncate_log(step)
            restores += 1

    if pending_save is not None:
        pending_save.join()
    return SupervisorResult(step, failures, restores, metrics_log, watchdog.flagged)
