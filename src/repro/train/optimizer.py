"""AdamW in pure JAX pytrees, with ZeRO-1 sharding and complex support.

Complex leaves (FNO spectral weights) use nu = E[|g|^2] (real) so the update
is phase-correct. ZeRO-1: optimizer moments are sharded over the data axis
on the largest divisible replicated dim of each leaf — ``zero1_specs``
derives the moment PartitionSpecs from the parameter specs, and XLA's SPMD
partitioner turns the update into reduce-scatter + all-gather form.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.tree import global_norm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable[[jax.Array], jax.Array]] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return sched


def init_opt_state(params) -> dict:
    def zeros_like_moment(p, second: bool):
        if jnp.issubdtype(p.dtype, jnp.complexfloating) and second:
            return jnp.zeros(p.shape, jnp.float32)  # nu = E[|g|^2] is real
        return jnp.zeros(p.shape, p.dtype)

    return {
        "mu": jax.tree.map(lambda p: zeros_like_moment(p, False), params),
        "nu": jax.tree.map(lambda p: zeros_like_moment(p, True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, step=None):
    """Returns (new_params, new_opt_state, stats)."""
    count = opt_state["count"] + 1
    step = count if step is None else step
    lr = cfg.lr_at(step)

    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, g, mu, nu):
        g32 = g.astype(mu.dtype)
        mu_n = b1 * mu + (1 - b1) * g32
        if jnp.issubdtype(p.dtype, jnp.complexfloating):
            g2 = jnp.real(g32 * jnp.conj(g32)).astype(nu.dtype)
        else:
            g2 = jnp.square(g32).astype(nu.dtype)
        nu_n = b2 * nu + (1 - b2) * g2
        mu_hat = mu_n / bc1
        nu_hat = nu_n / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps).astype(mu_hat.dtype)
        new_p = p - (lr * delta).astype(p.dtype)
        if cfg.weight_decay and not jnp.issubdtype(p.dtype, jnp.complexfloating):
            new_p = new_p - (lr * cfg.weight_decay * p.astype(jnp.float32)).astype(p.dtype)
        return new_p, mu_n, nu_n

    flat = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"mu": new_mu, "nu": new_nu, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )


# ---------------------------------------------------------------------------
# ZeRO-1: shard moments over the data axis.
# ---------------------------------------------------------------------------

def zero1_specs(param_spec_tree, abstract_params, mesh: Mesh, dp_axes=("data",)):
    """Moment PartitionSpecs: param spec + data-axis sharding on the largest
    still-replicated, divisible dim. Leaves with no such dim stay as-is."""
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def one(spec, p):
        if not isinstance(spec, P):
            spec = P()
        dims = list(spec) + [None] * (len(p.shape) - len(spec))
        best, best_size = None, 0
        for i, (d, s) in enumerate(zip(dims, p.shape)):
            if d is None and s % dp_size == 0 and s > best_size:
                best, best_size = i, s
        if best is not None:
            dims[best] = dp
        return P(*dims)

    return jax.tree.map(
        one, param_spec_tree, abstract_params, is_leaf=lambda x: isinstance(x, P)
    )


def opt_state_specs(param_spec_tree, abstract_params, mesh=None, dp_axes=("data",), zero1=True):
    """PartitionSpec tree matching init_opt_state's structure."""
    if zero1 and mesh is not None:
        moment = zero1_specs(param_spec_tree, abstract_params, mesh, dp_axes)
    else:
        moment = param_spec_tree
    return {"mu": moment, "nu": moment, "count": P()}
