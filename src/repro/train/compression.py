"""Error-feedback gradient compression for the data-parallel axis.

Top-k sparsification with local error feedback (Stich et al. / Deep
Gradient Compression lineage): each worker reduces only the k largest-
magnitude gradient entries (after adding its residual from previous
rounds); the rest accumulate locally. Wire cost drops from O(n) to
O(k * P) per tensor (values + indices all-gathered), which pays off on the
slow cross-pod axis where all-reducing full FNO spectral gradients (GBs)
dominates step time.

Use inside shard_map over the data axis:
    new_grads, new_err = compressed_psum_mean(grads, err, axis, ratio=0.01)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _topk_sparsify(g: jax.Array, k: int):
    flat = g.reshape(-1)
    mag = jnp.abs(flat)
    _, idx = jax.lax.top_k(mag, k)
    vals = flat[idx]
    return vals, idx


def compress_leaf(
    g: jax.Array, err: jax.Array, axis_name: str, ratio: float
) -> Tuple[jax.Array, jax.Array]:
    """One leaf: returns (mean-reduced dense grad, new local error)."""
    if g.size < 64:  # tiny leaves: dense psum, no point compressing
        return jax.lax.pmean(g, axis_name), jnp.zeros_like(err)
    corrected = (g + err).reshape(-1)
    k = max(1, int(g.size * ratio))
    vals, idx = _topk_sparsify(corrected.reshape(g.shape), k)
    # dense scatter of the local contribution, then psum: exact same result
    # as gathering (vals, idx) from all peers and scatter-adding — XLA emits
    # the efficient form; wire bytes are modeled in the benchmark.
    sparse = jnp.zeros_like(corrected).at[idx].set(vals)
    new_err = (corrected - sparse).reshape(g.shape)
    reduced = jax.lax.pmean(sparse.reshape(g.shape), axis_name)
    return reduced, new_err


def compressed_psum_mean(grads, err_state, axis_name: str, *, ratio: float = 0.01):
    """Pytree version. err_state matches grads' structure (zeros initially)."""
    pairs = jax.tree.map(
        lambda g, e: compress_leaf(g, e, axis_name, ratio), grads, err_state
    )
    reduced = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_err


def init_error_state(grads_abstract):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, g.dtype), grads_abstract)


def wire_bytes_dense(n_elems: int, itemsize: int, p: int) -> float:
    """Ring all-reduce bytes per device."""
    return 2.0 * n_elems * itemsize * (p - 1) / p


def wire_bytes_compressed(n_elems: int, itemsize: int, p: int, ratio: float) -> float:
    """All-gather of (vals f32 + idx i32) per peer."""
    k = max(1, int(n_elems * ratio))
    return float(k * (itemsize + 4) * (p - 1))
