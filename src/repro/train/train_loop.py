"""Train-step factory: grads + AdamW + (optional) grad accumulation, wired
with explicit shardings for AOT lowering and real runs alike."""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_specs


def make_train_step(
    loss_fn: Callable,          # (params, batch) -> (loss, metrics)
    opt_cfg: AdamWConfig,
    *,
    grad_accum: int = 1,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_accum > 1 the batch's leading dim is split into microbatches
    and gradients are averaged with a lax.scan (activation memory / accum).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, grads), acc_l + loss), metrics

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32 if not jnp.issubdtype(p.dtype, jnp.complexfloating) else p.dtype), params)
            (gsum, lsum), metrics = jax.lax.scan(body, (zero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            # scan stacks per-microbatch metrics along dim 0; report the
            # average over the whole batch, not just the last microbatch
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    return train_step


def shard_train_step(
    train_step: Callable,
    mesh: Mesh,
    param_specs,
    abstract_params,
    batch_specs,
    *,
    dp_axes=("data",),
    zero1: bool = True,
    donate: bool = True,
):
    """jit the step with explicit in/out shardings (params/opt donated)."""
    opt_specs = opt_state_specs(param_specs, abstract_params, mesh, dp_axes, zero1)

    def ns(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
            tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    in_shardings = (ns(param_specs), ns(opt_specs), ns(batch_specs))
    out_shardings = (ns(param_specs), ns(opt_specs), None)
    return jax.jit(
        train_step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        donate_argnums=(0, 1) if donate else (),
    )
