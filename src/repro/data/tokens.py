"""Deterministic synthetic token pipeline for the LM architectures.

Training at 1000+ nodes needs the data layer to be (a) deterministic by
step — so a restarted worker replays exactly the batch it crashed on
(the fault supervisor's contract), and (b) shardable by host — each host
materializes only its slice of the global batch. Both properties hold
here by deriving every batch from (seed, step) with a counter-based
generator; a store-backed variant reads packed token chunks from the
chunked ArrayStore instead.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.data.store import ArrayStore


class SyntheticTokens:
    """Zipf-ish random tokens, deterministic in (seed, step, host_slice)."""

    def __init__(
        self,
        vocab: int,
        global_batch: int,
        seq_len: int,
        *,
        seed: int = 0,
        host_slice: Tuple[int, int] = (0, 1),  # (host_index, host_count)
    ):
        self.vocab = vocab
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        hi, hn = host_slice
        assert global_batch % hn == 0
        self.local_batch = global_batch // hn
        self.host_index = hi

    def batch(self, step: int) -> dict:
        """-> {"tokens": [local_b, s], "targets": [local_b, s]} (int32)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )
        # zipf-like marginal so losses resemble text statistics
        u = rng.random((self.local_batch, self.seq_len + 1))
        toks = np.minimum(
            (self.vocab * u ** 2.2).astype(np.int64), self.vocab - 1
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class StoreTokens:
    """Packed-token reader over a chunked ArrayStore (one doc row per chunk)."""

    def __init__(self, root: str, seq_len: int, local_batch: int, *, seed: int = 0):
        self.store = ArrayStore.open(root)
        self.seq_len = seq_len
        self.local_batch = local_batch
        self.n_rows = self.store.shape[0]
        self.row_len = self.store.shape[1]
        assert self.row_len >= seq_len + 1
        self.seed = seed

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        rows = rng.integers(0, self.n_rows, size=self.local_batch)
        offs = rng.integers(0, self.row_len - self.seq_len - 1 + 1, size=self.local_batch)
        out = np.empty((self.local_batch, self.seq_len + 1), np.int32)
        for i, (r, o) in enumerate(zip(rows, offs)):
            out[i] = self.store.read_slice(
                (slice(int(r), int(r) + 1), slice(int(o), int(o) + self.seq_len + 1))
            )[0]
        return {"tokens": out[:, :-1], "targets": out[:, 1:]}
