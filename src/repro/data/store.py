"""Chunked N-D array store (zarr-style) on a filesystem "object store".

The paper writes each simulated training pair to blob storage with Zarr and
has every GPU read only its spatial chunk during training. This store
reproduces those two properties without external deps:

  * disjoint parallel writes: each worker writes whole chunks — chunk files
    are independent objects, so thousands of simulation tasks can write
    concurrently with no coordination;
  * partial reads: a training process reads only the chunks overlapping its
    shard's slice (model-parallel input loading).

Format: <root>/meta.json + <root>/c<idx0>_<idx1>_... (zstd-compressed raw).
Writes are atomic (tmp + rename) so interrupted tasks can be retried safely
— the idempotency the spot-VM story relies on. ``meta.json`` may carry
extra persisted keys (e.g. the datagen CLI's normalization ``stats``) via
``update_meta``.

IO accounting: every ``read_chunk`` bumps ``io_counters`` (chunk count,
logical bytes, compressed bytes on disk), which is how the loader tests
prove each shard touches only the chunks overlapping its slice.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Iterator, Sequence, Tuple

import numpy as np

# Multi-chunk read_slice fans file IO + decompression out over this many
# threads (chunks are independent objects; blob-store reads are latency-
# bound, so a small pool overlaps them well without oversubscribing CPU).
READ_POOL_WORKERS = 8

try:
    import zstandard as zstd

    _C = zstd.ZstdCompressor(level=3)
    _D = zstd.ZstdDecompressor()

    def _compress(b):
        return _C.compress(b)

    def _decompress(b):
        return _D.decompress(b)

except ImportError:  # pragma: no cover
    def _compress(b):
        return b

    def _decompress(b):
        return b


class ArrayStore:
    def __init__(self, root: str, shape, dtype, chunks, meta: dict | None = None):
        self.root = root
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self.chunks = tuple(chunks)
        assert len(self.chunks) == len(self.shape)
        self.meta = dict(meta) if meta else {}
        self.io_counters = {"chunks_read": 0, "bytes_read": 0, "bytes_on_disk": 0}
        self._io_lock = threading.Lock()  # keeps io_counters exact under the pool
        self._pool: ThreadPoolExecutor | None = None
        self._watermark = 0  # complete-prefix length last observed (monotone)

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def create(cls, root: str, shape, dtype, chunks) -> "ArrayStore":
        os.makedirs(root, exist_ok=True)
        meta = {"shape": list(shape), "dtype": np.dtype(dtype).str, "chunks": list(chunks)}
        tmp = os.path.join(root, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.rename(tmp, os.path.join(root, "meta.json"))
        return cls(root, shape, dtype, chunks, meta)

    @classmethod
    def open(cls, root: str) -> "ArrayStore":
        with open(os.path.join(root, "meta.json")) as f:
            meta = json.load(f)
        return cls(root, meta["shape"], meta["dtype"], meta["chunks"], meta)

    def update_meta(self, **extra) -> None:
        """Persist extra metadata keys (atomic rewrite of meta.json)."""
        self.meta.update(extra)
        merged = {
            "shape": list(self.shape),
            "dtype": self.dtype.str,
            "chunks": list(self.chunks),
            **{k: v for k, v in self.meta.items() if k not in ("shape", "dtype", "chunks")},
        }
        tmp = os.path.join(self.root, f"meta.json.tmp{os.getpid()}")
        with open(tmp, "w") as f:
            json.dump(merged, f)
        os.rename(tmp, os.path.join(self.root, "meta.json"))
        self.meta = merged

    # -- chunk io ----------------------------------------------------------
    def _chunk_path(self, idx: Sequence[int]) -> str:
        return os.path.join(self.root, "c" + "_".join(str(i) for i in idx))

    def chunk_grid(self) -> Tuple[int, ...]:
        return tuple(-(-s // c) for s, c in zip(self.shape, self.chunks))

    def _chunk_shape(self, idx: Sequence[int]) -> Tuple[int, ...]:
        return tuple(
            min(self.chunks[d], self.shape[d] - idx[d] * self.chunks[d])
            for d in range(len(idx))
        )

    def write_chunk(self, idx: Sequence[int], data: np.ndarray):
        expected = self._chunk_shape(idx)
        assert data.shape == expected, (data.shape, expected)
        path = self._chunk_path(idx)
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(_compress(np.ascontiguousarray(data.astype(self.dtype)).tobytes()))
        os.rename(tmp, path)  # atomic publish -> retried tasks are safe

    def read_chunk(self, idx: Sequence[int]) -> np.ndarray:
        shape = self._chunk_shape(idx)
        path = self._chunk_path(idx)
        try:
            with open(path, "rb") as f:
                raw_disk = f.read()
        except FileNotFoundError:
            raise FileNotFoundError(
                f"chunk {tuple(idx)} of store {self.root!r} is missing "
                f"(expected file {path}); the sample was never written or "
                f"its datagen task is still in flight"
            ) from None
        raw = _decompress(raw_disk)
        out = np.frombuffer(raw, dtype=self.dtype).reshape(shape)
        with self._io_lock:
            self.io_counters["chunks_read"] += 1
            self.io_counters["bytes_read"] += out.nbytes
            self.io_counters["bytes_on_disk"] += len(raw_disk)
        return out

    def has_chunk(self, idx: Sequence[int]) -> bool:
        return os.path.exists(self._chunk_path(idx))

    def reset_io_counters(self) -> None:
        self.io_counters = {"chunks_read": 0, "bytes_read": 0, "bytes_on_disk": 0}

    # -- convenience: leading-dim samples + arbitrary slices ---------------
    def sample_chunk_indices(self, i: int) -> Iterator[Tuple[int, ...]]:
        """All chunk indices in leading-dim chunk row i (== sample i when
        chunks[0] == 1, the one-sim-result-per-task layout)."""
        grid = self.chunk_grid()
        return (
            (i,) + rest
            for rest in itertools.product(*[range(g) for g in grid[1:]])
        )

    def sample_complete(self, i: int) -> bool:
        """True iff every chunk of sample i has been published."""
        return all(self.has_chunk(idx) for idx in self.sample_chunk_indices(i))

    def write_sample(self, i: int, data: np.ndarray):
        """Write sample i when chunks[0] == 1 (one sim result per task).

        The sample may span several spatial chunks (the store's chunking
        along x/y is what lets each training shard read only its pencil);
        each chunk file is published atomically, so a retried task simply
        overwrites whatever subset its predecessor managed to write.
        """
        assert self.chunks[0] == 1
        if data.ndim == len(self.shape) - 1:
            data = data[None]
        assert data.shape == (1,) + self.shape[1:], (data.shape, self.shape)
        for idx in self.sample_chunk_indices(i):
            sel = (slice(0, 1),) + tuple(
                slice(idx[d] * self.chunks[d], idx[d] * self.chunks[d] + s)
                for d, s in list(enumerate(self._chunk_shape(idx)))[1:]
            )
            self.write_chunk(idx, data[sel])

    def read_slice(self, slices: Sequence[slice]) -> np.ndarray:
        """Read an arbitrary rectangular slice (touches only needed chunks).

        Only unit-step slices are supported; the chunk-copy math below
        assumes contiguous ranges, so a stepped slice would silently return
        wrong data — reject it instead.
        """
        slices = tuple(
            slice(*sl.indices(self.shape[d])) for d, sl in enumerate(slices)
        )
        for d, sl in enumerate(slices):
            if sl.step != 1:
                raise ValueError(
                    f"read_slice supports only unit-step slices; got step "
                    f"{sl.step} in dim {d} of {self.root!r}"
                )
        out_shape = tuple(sl.stop - sl.start for sl in slices)
        out = np.empty(out_shape, self.dtype)
        lo = [sl.start // c for sl, c in zip(slices, self.chunks)]
        hi = [(sl.stop - 1) // c for sl, c in zip(slices, self.chunks)]
        indices = list(
            itertools.product(*[range(a, b + 1) for a, b in zip(lo, hi)])
        )

        def copy_one(idx):
            # chunks are independent objects and each writes a DISJOINT
            # rectangle of ``out``, so the copies can run concurrently;
            # read_chunk keeps io_counters exact under its lock
            chunk = self.read_chunk(idx)
            src, dst = [], []
            for d in range(len(idx)):
                c0 = idx[d] * self.chunks[d]
                s0 = max(slices[d].start, c0)
                s1 = min(slices[d].stop, c0 + chunk.shape[d])
                src.append(slice(s0 - c0, s1 - c0))
                dst.append(slice(s0 - slices[d].start, s1 - slices[d].start))
            out[tuple(dst)] = chunk[tuple(src)]

        if len(indices) == 1:
            copy_one(indices[0])
        else:
            for f in [self._read_pool().submit(copy_one, i) for i in indices]:
                f.result()  # re-raises missing-chunk errors with attribution
        return out

    def _read_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=READ_POOL_WORKERS,
                thread_name_prefix="arraystore-read",
            )
        return self._pool

    def n_complete(self) -> int:
        return sum(
            1 for i in range(self.chunk_grid()[0]) if self.sample_complete(i)
        )

    # -- visibility (online/streaming training) ----------------------------
    def complete_watermark(self) -> int:
        """Length of the complete PREFIX of samples: the largest w such that
        samples 0..w-1 are all published.

        Incremental: chunk publishes are atomic and never retracted, so a
        sample observed complete stays complete — each call resumes the scan
        at the last known watermark instead of re-polling every chunk file
        (O(new samples) per call, not O(n * chunks)). A streaming reader can
        therefore poll this cheaply while datagen is still writing.
        """
        n = self.chunk_grid()[0]
        w = self._watermark
        while w < n and self.sample_complete(w):
            w += 1
        self._watermark = w
        return w

    def wait_for_samples(
        self, k: int, timeout: float | None = None, poll_s: float = 0.02
    ) -> int:
        """Block until the complete prefix reaches ``k`` samples (or the full
        store, if smaller); returns the watermark. Raises TimeoutError if
        ``timeout`` seconds pass first — a stuck simulator should fail the
        training job loudly, not hang it."""
        target = min(int(k), self.chunk_grid()[0])
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            w = self.complete_watermark()
            if w >= target:
                return w
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"store {self.root!r}: waited {timeout}s for {target} "
                    f"complete samples, have {w}"
                )
            time.sleep(poll_s)
