"""Chunked N-D array store (zarr-style) on a filesystem "object store".

The paper writes each simulated training pair to blob storage with Zarr and
has every GPU read only its spatial chunk during training. This store
reproduces those two properties without external deps:

  * disjoint parallel writes: each worker writes whole chunks — chunk files
    are independent objects, so thousands of simulation tasks can write
    concurrently with no coordination;
  * partial reads: a training process reads only the chunks overlapping its
    shard's slice (model-parallel input loading).

Format: <root>/meta.json + <root>/c<idx0>_<idx1>_... (zstd-compressed raw).
Writes are atomic (tmp + rename) so interrupted tasks can be retried safely
— the idempotency the spot-VM story relies on.
"""
from __future__ import annotations

import json
import os
from typing import Sequence, Tuple

import numpy as np

try:
    import zstandard as zstd

    _C = zstd.ZstdCompressor(level=3)
    _D = zstd.ZstdDecompressor()

    def _compress(b):
        return _C.compress(b)

    def _decompress(b):
        return _D.decompress(b)

except ImportError:  # pragma: no cover
    def _compress(b):
        return b

    def _decompress(b):
        return b


class ArrayStore:
    def __init__(self, root: str, shape, dtype, chunks):
        self.root = root
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self.chunks = tuple(chunks)
        assert len(self.chunks) == len(self.shape)

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def create(cls, root: str, shape, dtype, chunks) -> "ArrayStore":
        os.makedirs(root, exist_ok=True)
        meta = {"shape": list(shape), "dtype": np.dtype(dtype).str, "chunks": list(chunks)}
        tmp = os.path.join(root, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.rename(tmp, os.path.join(root, "meta.json"))
        return cls(root, shape, dtype, chunks)

    @classmethod
    def open(cls, root: str) -> "ArrayStore":
        with open(os.path.join(root, "meta.json")) as f:
            meta = json.load(f)
        return cls(root, meta["shape"], meta["dtype"], meta["chunks"])

    # -- chunk io ----------------------------------------------------------
    def _chunk_path(self, idx: Sequence[int]) -> str:
        return os.path.join(self.root, "c" + "_".join(str(i) for i in idx))

    def chunk_grid(self) -> Tuple[int, ...]:
        return tuple(-(-s // c) for s, c in zip(self.shape, self.chunks))

    def write_chunk(self, idx: Sequence[int], data: np.ndarray):
        expected = tuple(
            min(self.chunks[d], self.shape[d] - idx[d] * self.chunks[d])
            for d in range(len(idx))
        )
        assert data.shape == expected, (data.shape, expected)
        path = self._chunk_path(idx)
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(_compress(np.ascontiguousarray(data.astype(self.dtype)).tobytes()))
        os.rename(tmp, path)  # atomic publish -> retried tasks are safe

    def read_chunk(self, idx: Sequence[int]) -> np.ndarray:
        shape = tuple(
            min(self.chunks[d], self.shape[d] - idx[d] * self.chunks[d])
            for d in range(len(idx))
        )
        with open(self._chunk_path(idx), "rb") as f:
            raw = _decompress(f.read())
        return np.frombuffer(raw, dtype=self.dtype).reshape(shape)

    def has_chunk(self, idx: Sequence[int]) -> bool:
        return os.path.exists(self._chunk_path(idx))

    # -- convenience: leading-dim samples + arbitrary slices ---------------
    def write_sample(self, i: int, data: np.ndarray):
        """Write sample i when chunks[0] == 1 (one sim result per task)."""
        assert self.chunks[0] == 1
        self.write_chunk((i,) + (0,) * (len(self.shape) - 1), data[None] if data.ndim == len(self.shape) - 1 else data)

    def read_slice(self, slices: Sequence[slice]) -> np.ndarray:
        """Read an arbitrary rectangular slice (touches only needed chunks)."""
        slices = tuple(
            slice(*sl.indices(self.shape[d])) for d, sl in enumerate(slices)
        )
        out_shape = tuple(sl.stop - sl.start for sl in slices)
        out = np.empty(out_shape, self.dtype)
        lo = [sl.start // c for sl, c in zip(slices, self.chunks)]
        hi = [(sl.stop - 1) // c for sl, c in zip(slices, self.chunks)]
        import itertools

        for idx in itertools.product(*[range(a, b + 1) for a, b in zip(lo, hi)]):
            chunk = self.read_chunk(idx)
            src, dst = [], []
            for d in range(len(idx)):
                c0 = idx[d] * self.chunks[d]
                s0 = max(slices[d].start, c0)
                s1 = min(slices[d].stop, c0 + chunk.shape[d])
                src.append(slice(s0 - c0, s1 - c0))
                dst.append(slice(s0 - slices[d].start, s1 - slices[d].start))
            out[tuple(dst)] = chunk[tuple(src)]
        return out

    def n_complete(self) -> int:
        return sum(
            1 for i in range(self.chunk_grid()[0])
            if self.has_chunk((i,) + (0,) * (len(self.shape) - 1))
        )
