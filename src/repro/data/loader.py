"""Domain-decomposed dataset loader: every device reads only its chunk.

The paper's training loop has each GPU pull just its spatial shard of every
training pair straight from blob storage (Zarr chunks), instead of every
host materializing the whole dataset. ``ShardedDatasetLoader`` reproduces
that contract on top of ``ArrayStore``:

  * shard-local IO — for each device of the batch sharding, only the store
    chunks overlapping that device's ``(mx, my)`` pencil (and its slice of
    the batch dim) are read, via ``ArrayStore.read_slice``;
  * global assembly — the per-shard host blocks become one globally-sharded
    ``jax.Array`` through ``compat.make_global_array`` (replicated shards
    are fetched once), so the jitted step sees data already laid out for
    its in_shardings and no resharding collective is emitted;
  * overlap — a background thread prefetches the next batches' host blocks
    (double-buffered by default) while the accelerator computes; assembly
    and device transfer stay on the caller's thread;
  * determinism — batch t is a pure function of (seed, t): samples follow
    per-epoch ``PRNG(seed, epoch)`` permutations, so a restarted worker
    replays exactly the batch it crashed on (the fault supervisor's
    contract) and every process draws the same global order;
  * streaming — with a ``StreamingSchedule``, batches draw from the
    complete-prefix watermark of stores that datagen is STILL writing
    (Meyer-et-al online training); the recorded per-step watermarks keep
    batch t replayable after restore despite the race with the simulator;
  * normalization — per-channel (mean, std) from the store's ``meta.json``
    ``stats`` (written by the datagen CLI's streaming Welford pass) are
    applied on the host blocks, shard-locally.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import compat
from repro.data.store import ArrayStore


class NdArraySource:
    """In-memory stand-in for an ArrayStore (synthetic-data path): exposes
    the same ``shape`` / ``read_slice`` / ``meta`` surface over an ndarray,
    so the loader's sharded assembly and prefetch are exercised identically
    whether samples come from blob storage or RAM."""

    def __init__(self, array: np.ndarray, stats: Optional[dict] = None):
        self.array = np.asarray(array)
        self.shape = self.array.shape
        self.meta = {"stats": stats} if stats else {}

    def read_slice(self, slices: Sequence[slice]) -> np.ndarray:
        return self.array[tuple(slices)]


NORMALIZER_KINDS = ("meanstd", "absmax")


class Normalizer:
    """Invertible per-channel affine normalizer from persisted store stats.

    The ``normalizer`` kind in a store's ``meta.json`` selects the scheme:
    ``meanstd`` (default) encodes ``(x - mean) / std`` from the Welford
    stats; ``absmax`` encodes ``x / absmax`` (the paper normalizes NS
    targets by their max). ``decode`` inverts, which is what serving uses
    to return predictions in physical units. Stats arrays are shaped to
    broadcast over ``[b, c, *spatial]``.
    """

    def __init__(self, mean, scale, identity: bool = False):
        self.mean = np.asarray(mean, np.float32)
        self.scale = np.asarray(scale, np.float32)
        self.identity = identity

    @classmethod
    def from_stats(cls, stats, kind: str = "meanstd", ndim: int = 6) -> "Normalizer":
        if not stats:
            return cls(0.0, 1.0, identity=True)
        if kind not in NORMALIZER_KINDS:
            raise ValueError(
                f"unknown normalizer kind {kind!r}; expected one of "
                f"{NORMALIZER_KINDS}"
            )
        bshape = (1, -1) + (1,) * (ndim - 2)
        if kind == "absmax":
            if "absmax" not in stats:
                raise ValueError(
                    "normalizer 'absmax' requested but the persisted stats "
                    "carry no 'absmax' field (regenerate the store with the "
                    "current datagen, which tracks per-channel max|x|)"
                )
            mean = np.zeros(len(stats["absmax"]), np.float32).reshape(bshape)
            scale = np.maximum(
                np.asarray(stats["absmax"], np.float32).reshape(bshape), 1e-6
            )
        else:
            mean = np.asarray(stats["mean"], np.float32).reshape(bshape)
            scale = np.maximum(
                np.asarray(stats["std"], np.float32).reshape(bshape), 1e-6
            )
        return cls(mean, scale)

    @classmethod
    def from_source(cls, source) -> "Normalizer":
        meta = getattr(source, "meta", None) or {}
        return cls.from_stats(
            meta.get("stats"),
            meta.get("normalizer", "meanstd"),
            len(source.shape),
        )

    def encode(self, x: np.ndarray) -> np.ndarray:
        return np.asarray((x - self.mean) / self.scale, np.float32)

    def decode(self, y: np.ndarray) -> np.ndarray:
        return np.asarray(y * self.scale + self.mean, np.float32)


def _norm_params(source):
    """(mean, scale) broadcastable over [b, c, ...] or None, honoring the
    store's persisted ``normalizer`` kind."""
    n = Normalizer.from_source(source)
    return None if n.identity else (n.mean, n.scale)


class _Prefetcher:
    """Background producer of ``fetch(step)`` results, double-buffered.

    The producer runs ``depth`` steps ahead of the consumer. ``get(step)``
    normally pops a ready result; a non-sequential request (restart from a
    checkpointed step) resets the pipeline and computes synchronously once.
    """

    def __init__(self, fetch, depth: int = 2):
        self._fetch = fetch
        self._depth = max(1, depth)
        self._lock = threading.Lock()
        self._ready: Dict[int, object] = {}
        self._cv = threading.Condition(self._lock)
        self._next = 0          # next step the producer should fetch
        self._gen = 0           # bumped on reset; stale results are dropped
        self._stopped = False
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            with self._cv:
                while not self._stopped and len(self._ready) >= self._depth:
                    self._cv.wait()
                if self._stopped:
                    return
                step, gen = self._next, self._gen
                self._next += 1
            try:
                data = self._fetch(step)
            except BaseException as e:  # surface IO errors to the consumer
                with self._cv:
                    self._error = e
                    self._stopped = True
                    self._cv.notify_all()
                return
            with self._cv:
                if gen == self._gen:  # drop results from before a reset
                    self._ready[step] = data
                    self._cv.notify_all()

    def _restart(self, step: int):
        """Reset the pipeline to produce step+1 onwards (lock held). Clears
        a dead producer's error so one bad background fetch never poisons
        later steps — the caller fetches ``step`` synchronously, which
        re-raises with correct attribution if THIS step is the broken one."""
        self._gen += 1
        self._ready.clear()
        self._error = None
        self._next = step + 1
        self._cv.notify_all()
        if self._stopped:
            self._stopped = False
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    def get(self, step: int):
        with self._cv:
            if step in self._ready:
                data = self._ready.pop(step)
                self._cv.notify_all()
                return data
            # sequential requests keep the pipeline: the producer is either
            # computing this step (step == _next - 1) or about to claim it
            # (step == _next with queue space); anything else — an
            # out-of-order replay after restore, a forward jump, or a dead
            # producer — resets and fetches synchronously once.
            sequential = (
                self._error is None
                and not self._stopped
                and (
                    step == self._next - 1
                    or (step == self._next and len(self._ready) < self._depth)
                )
            )
            if not sequential:
                self._restart(step)
        if not sequential:
            return self._fetch(step)
        with self._cv:
            while (
                step not in self._ready
                and not self._stopped
                and self._error is None
            ):
                self._cv.wait()
            if step in self._ready:
                data = self._ready.pop(step)
                self._cv.notify_all()
                return data
            self._restart(step)
        return self._fetch(step)

    def stop(self):
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        self._thread.join(timeout=5)


class StreamingSchedule:
    """Deterministic batch schedule over the currently-visible sample prefix.

    Online training (Meyer et al.: stream samples into training as the
    simulator produces them) needs a sample schedule that (a) only ever
    draws samples whose chunks are fully published, (b) blocks — with a
    stall counter surfaced in metrics — when training outpaces simulation,
    and (c) stays a pure replayable function of ``step`` after a checkpoint
    restore, which is the fault supervisor's contract.

    (c) is the subtle one: visibility is a race against the simulator, so
    the schedule RECORDS the complete-prefix watermark the first time each
    step is drawn (``watermark_log``). Batch ids are then a pure function of
    ``(seed, step, watermark_log[step])``; replaying the same log against
    the finished store — or after a crash restore, against the same run —
    reproduces every batch bit-identically. Pass ``log_path`` to persist the
    log (append-only jsonl, one entry per newly recorded step) so a
    restarted process replays too. Note the log fixes the sample SCHEDULE;
    normalization stats are read once at loader construction, so a restarted
    process must reuse the same stats snapshot (train.py --online persists
    one next to this log) for the batch VALUES to match as well.
    """

    def __init__(
        self,
        stores: Sequence[object],
        batch_size: int,
        *,
        seed: int = 0,
        min_visible: Optional[int] = None,
        timeout: Optional[float] = None,
        poll_s: float = 0.02,
        watermark_log: Optional[Dict[int, int]] = None,
        log_path: Optional[str] = None,
    ):
        self.stores = list(stores)
        assert self.stores, "StreamingSchedule needs at least one store"
        self.batch_size = int(batch_size)
        self.seed = seed
        # back-pressure threshold: don't step until this many samples exist
        # (clamped to the smallest store so a batch larger than the dataset
        # oversamples the full prefix instead of waiting forever)
        cap = min(int(s.shape[0]) for s in self.stores)
        self.min_visible = max(
            1, min(min_visible if min_visible else batch_size, cap)
        )
        self.timeout = timeout
        self.poll_s = poll_s
        self.watermark_log: Dict[int, int] = {
            int(k): int(v) for k, v in (watermark_log or {}).items()
        }
        self.log_path = log_path
        if log_path and os.path.exists(log_path):
            with open(log_path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except ValueError:
                        continue  # torn tail line from a crash mid-append
                    self.watermark_log[int(rec["step"])] = int(rec["w"])
        self.stalls = 0
        self.stall_s = 0.0
        self._lock = threading.Lock()

    # -- visibility --------------------------------------------------------
    def visible_now(self) -> int:
        """Samples visible in EVERY store (min over complete prefixes)."""
        return min(s.complete_watermark() for s in self.stores)

    def _persist_entry(self, step: int, w: int) -> None:
        """Append one record — O(1) per step, unlike rewriting the dict."""
        if not self.log_path:
            return
        with open(self.log_path, "a") as f:
            f.write(json.dumps({"step": step, "w": w}) + "\n")

    def watermark(self, step: int) -> int:
        """Visible-count watermark for ``step``: recorded once, replayed
        forever after. Blocks (back-pressure) while fewer than
        ``min_visible`` samples are published — WITHOUT holding the lock,
        so replay lookups of already-recorded steps from other threads
        (trainer vs prefetcher) never wait on the simulator."""
        while True:
            with self._lock:
                w = self.watermark_log.get(step)
                if w is not None:
                    return w
                w = self.visible_now()
                if w >= self.min_visible:
                    self.watermark_log[step] = w
                    self._persist_entry(step, w)
                    return w
                self.stalls += 1
            t0 = time.monotonic()
            for s in self.stores:
                s.wait_for_samples(
                    self.min_visible, timeout=self.timeout, poll_s=self.poll_s
                )
            with self._lock:
                self.stall_s += time.monotonic() - t0

    # -- the schedule itself ----------------------------------------------
    def sample_ids(self, step: int) -> np.ndarray:
        """Batch ids for ``step``: uniform over the visible prefix, pure in
        (seed, step, recorded watermark). Draws without replacement when the
        prefix is large enough, with replacement while it is still smaller
        than the batch (the price of starting before the data exists)."""
        w = self.watermark(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(step), int(w)])
        )
        return rng.choice(w, size=self.batch_size, replace=w < self.batch_size)

    def metrics(self) -> dict:
        with self._lock:
            return {
                "stalls": self.stalls,
                "stall_s": round(self.stall_s, 4),
                "max_step_recorded": max(self.watermark_log, default=-1),
                "last_watermark": self.watermark_log[
                    max(self.watermark_log)
                ] if self.watermark_log else 0,
            }


class ShardedDatasetLoader:
    """Assemble globally-sharded training batches from chunked stores.

    ``sources`` maps batch keys to ArrayStore-like objects whose layout is
    ``[n_samples, channels, *spatial]``; ``specs`` maps the same keys to the
    batch PartitionSpec on ``mesh`` (dim 0 = batch, rest = sample dims), the
    same specs handed to ``shard_train_step`` — one source of truth for the
    data layout on both sides.
    """

    def __init__(
        self,
        sources: Dict[str, object],
        mesh: Mesh,
        batch_size: int,
        specs: Dict[str, P],
        *,
        seed: int = 0,
        shuffle: bool = True,
        normalize: Sequence[str] = ("x",),
        prefetch: int = 2,
        device_filter=None,
        schedule: Optional[StreamingSchedule] = None,
    ):
        assert set(sources) == set(specs), (sources.keys(), specs.keys())
        self.sources = dict(sources)
        self.mesh = mesh
        self.batch_size = int(batch_size)
        self.specs = dict(specs)
        self.seed = seed
        self.shuffle = shuffle
        self.schedule = schedule
        self._norm = {
            k: _norm_params(self.sources[k]) if k in tuple(normalize) else None
            for k in self.sources
        }
        ns = {s.shape[0] for s in self.sources.values()}
        if len(ns) != 1:
            raise ValueError(f"sources disagree on sample count: {ns}")
        self.n_samples = ns.pop()
        if self.n_samples < 1:
            raise ValueError("empty dataset")
        self._device_filter = device_filter
        self._shardings = {
            k: NamedSharding(mesh, spec) for k, spec in self.specs.items()
        }
        self._global_shapes = {
            k: (self.batch_size,) + tuple(self.sources[k].shape[1:])
            for k in self.sources
        }
        for k, sharding in self._shardings.items():
            # fail fast on indivisible layouts (the analog of
            # CartPartition.validate for the data pipeline)
            sharding.shard_shape(self._global_shapes[k])
        self._shard_plan = {}
        self._prefetcher = (
            _Prefetcher(self._read_host_batch, depth=prefetch) if prefetch else None
        )

    # -- deterministic sample schedule -------------------------------------
    def sample_ids(self, step: int) -> np.ndarray:
        """Global sample ids of batch ``step`` (pure function of seed/step;
        in streaming mode, delegated to the schedule's watermark log)."""
        if self.schedule is not None:
            return self.schedule.sample_ids(step)
        n, b = self.n_samples, self.batch_size
        positions = np.arange(step * b, (step + 1) * b)
        epochs, offsets = positions // n, positions % n
        ids = np.empty(b, np.int64)
        for e in np.unique(epochs):
            if self.shuffle:
                perm = np.random.default_rng(
                    np.random.SeedSequence([self.seed, int(e)])
                ).permutation(n)
            else:
                perm = np.arange(n)
            sel = epochs == e
            ids[sel] = perm[offsets[sel]]
        return ids

    def epoch_of(self, step: int) -> int:
        return (step * self.batch_size) // self.n_samples

    # -- shard-local IO ----------------------------------------------------
    def _shard_indices(self, key: str):
        """Unique shard index tuples this process must read for ``key``
        (static across steps, so computed once)."""
        cached = self._shard_plan.get(key)
        if cached is not None:
            return cached
        sharding = self._shardings[key]
        shape = self._global_shapes[key]
        index_map = sharding.addressable_devices_indices_map(shape)
        if self._device_filter is not None:
            index_map = {
                d: idx for d, idx in index_map.items() if self._device_filter(d)
            }
        seen = {}
        for _, idx in index_map.items():
            norm = tuple(
                sl.indices(dim) for sl, dim in zip(idx, shape)
            )
            seen.setdefault(norm, tuple(slice(a, b, c) for a, b, c in norm))
        self._shard_plan[key] = list(seen.values())
        return self._shard_plan[key]

    def _read_shard(self, key: str, ids: np.ndarray, index) -> np.ndarray:
        """Read ONE device shard: only the chunks overlapping ``index``.

        The batch dim indexes the shuffled schedule, so each sample row is a
        separate (possibly non-contiguous) store read of the shard's spatial
        slice — exactly the chunks under this device's pencil.
        """
        source = self.sources[key]
        bsl, rest = index[0], tuple(index[1:])
        rows = ids[bsl]
        out = np.empty(
            (len(rows),) + tuple(sl.stop - sl.start for sl in rest), np.float32
        )
        for j, sample in enumerate(rows):
            out[j] = source.read_slice(
                (slice(int(sample), int(sample) + 1),) + rest
            )[0]
        norm = self._norm.get(key)
        if norm is not None:
            mean, std = norm
            csl = rest[0] if rest else slice(None)
            out = (out - mean[:, csl]) / std[:, csl]
        return np.ascontiguousarray(out, np.float32)

    def _read_host_batch(self, step: int):
        """Host-side blocks for every unique addressable shard (IO thread)."""
        ids = self.sample_ids(step)
        blocks = {}
        for key in self.sources:
            blocks[key] = {
                tuple((s.start, s.stop) for s in index): self._read_shard(
                    key, ids, index
                )
                for index in self._shard_indices(key)
            }
        return {"ids": ids, "blocks": blocks}

    # -- public API --------------------------------------------------------
    def batch(self, step: int) -> Dict[str, jax.Array]:
        """Globally-sharded batch for ``step`` (deterministic, prefetched)."""
        host = (
            self._prefetcher.get(step)
            if self._prefetcher is not None
            else self._read_host_batch(step)
        )

        out = {}
        ids = host["ids"]
        for key in self.sources:
            blocks = host["blocks"][key]

            def fetch(index, _key=key, _blocks=blocks):
                block = _blocks.get(tuple((s.start, s.stop) for s in index))
                if block is None:
                    # shard not prefetched (e.g. outside device_filter when
                    # simulating one process of a multi-host job): read it
                    # on demand through the same chunk-local path
                    block = self._read_shard(_key, ids, index)
                return block

            out[key] = compat.make_global_array(
                self._global_shapes[key], self._shardings[key], fetch
            )
        return out

    def close(self):
        if self._prefetcher is not None:
            self._prefetcher.stop()
            self._prefetcher = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
