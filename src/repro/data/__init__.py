from repro.data.loader import NdArraySource, ShardedDatasetLoader  # noqa: F401
from repro.data.store import ArrayStore  # noqa: F401
from repro.data.tokens import StoreTokens, SyntheticTokens  # noqa: F401
