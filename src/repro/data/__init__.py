from repro.data.loader import (  # noqa: F401
    NdArraySource, ShardedDatasetLoader, StreamingSchedule,
)
from repro.data.store import ArrayStore  # noqa: F401
from repro.data.tokens import StoreTokens, SyntheticTokens  # noqa: F401
