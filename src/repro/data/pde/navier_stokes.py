"""3-D incompressible Navier-Stokes around an immersed sphere (WaterLily
stand-in, paper §V-A).

Pseudo-spectral on a periodic box with Brinkman penalization for the
sphere: du/dt + (u.grad)u = -grad p + nu lap u - chi/eta (u - 0), where chi
is the sphere mask. A uniform background inflow U0 drives the wake; the
incompressibility projection is exact in Fourier space; viscosity uses an
integrating factor; time stepping is RK2. Output is the vorticity magnitude
on an nt-frame time grid — the paper's training target (input = the binary
sphere mask).

This replaces WaterLily's multigrid immersed-boundary scheme with a
TPU/JAX-friendly formulation (FFTs and elementwise ops; no unstructured
solver), which is the documented hardware adaptation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class NSConfig:
    n: int = 32                 # grid points per dim
    nt_frames: int = 8          # output time frames
    steps_per_frame: int = 10
    dt: float = 0.01
    viscosity: float = 5e-3
    u0: float = 1.0             # background inflow (x direction)
    penalization: float = 1e-2  # Brinkman eta
    sphere_radius: float = 0.12 # in box units [0,1)


def sphere_mask(cfg: NSConfig, center: jnp.ndarray) -> jnp.ndarray:
    """Binary mask [n,n,n] of the immersed sphere (periodic distance)."""
    g = (jnp.arange(cfg.n) + 0.5) / cfg.n
    x, y, z = jnp.meshgrid(g, g, g, indexing="ij")
    def pdist(a, c):
        d = jnp.abs(a - c)
        return jnp.minimum(d, 1.0 - d)
    r2 = pdist(x, center[0]) ** 2 + pdist(y, center[1]) ** 2 + pdist(z, center[2]) ** 2
    return (r2 < cfg.sphere_radius ** 2).astype(jnp.float32)


def _wavenumbers(n: int):
    k = jnp.fft.fftfreq(n, d=1.0 / n) * 2 * jnp.pi
    kx, ky, kz = jnp.meshgrid(k, k, k, indexing="ij")
    k2 = kx ** 2 + ky ** 2 + kz ** 2
    return kx, ky, kz, jnp.where(k2 == 0, 1.0, k2)


def _project(uh, kx, ky, kz, k2):
    """Leray projection onto divergence-free fields."""
    div = kx * uh[0] + ky * uh[1] + kz * uh[2]
    return jnp.stack([uh[0] - kx * div / k2, uh[1] - ky * div / k2, uh[2] - kz * div / k2])


def _rhs(uh, chi, cfg, kx, ky, kz, k2):
    u = jnp.fft.ifftn(uh, axes=(1, 2, 3)).real
    # advection (u . grad) u, derivatives in spectral space
    def ddx(f_hat, kvec):
        return jnp.fft.ifftn(1j * kvec * f_hat, axes=(0, 1, 2)).real
    adv = []
    for i in range(3):
        gx = ddx(uh[i], kx)
        gy = ddx(uh[i], ky)
        gz = ddx(uh[i], kz)
        adv.append(u[0] * gx + u[1] * gy + u[2] * gz)
    adv = jnp.stack(adv)
    # Brinkman: drive velocity to zero inside the solid
    pen = -(chi / cfg.penalization) * u
    rhs = jnp.fft.fftn(-adv + pen, axes=(1, 2, 3))
    return _project(rhs, kx, ky, kz, k2)


def simulate(center: jnp.ndarray, cfg: NSConfig = NSConfig()) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (sphere mask [n,n,n], vorticity magnitude [n,n,n,nt])."""
    chi = sphere_mask(cfg, center)
    kx, ky, kz, k2 = _wavenumbers(cfg.n)
    visc = jnp.exp(-cfg.viscosity * k2 * cfg.dt)

    u0 = jnp.zeros((3, cfg.n, cfg.n, cfg.n), jnp.float32).at[0].set(cfg.u0)
    # small perturbation to break symmetry
    u0 = u0.at[1].add(0.01 * jnp.sin(2 * jnp.pi * jnp.linspace(0, 1, cfg.n))[None, :, None])
    uh = jnp.fft.fftn(u0, axes=(1, 2, 3))
    uh = _project(uh, kx, ky, kz, k2)

    def step(uh, _):
        r1 = _rhs(uh, chi, cfg, kx, ky, kz, k2)
        mid = (uh + 0.5 * cfg.dt * r1) * jnp.sqrt(visc)
        r2 = _rhs(mid, chi, cfg, kx, ky, kz, k2)
        new = (uh + cfg.dt * r2 * jnp.sqrt(visc)) * visc
        return new, None

    def frame(uh, _):
        uh, _ = jax.lax.scan(step, uh, None, length=cfg.steps_per_frame)
        # vorticity magnitude
        wx = jnp.fft.ifftn(1j * (ky * uh[2] - kz * uh[1]), axes=(0, 1, 2)).real
        wy = jnp.fft.ifftn(1j * (kz * uh[0] - kx * uh[2]), axes=(0, 1, 2)).real
        wz = jnp.fft.ifftn(1j * (kx * uh[1] - ky * uh[0]), axes=(0, 1, 2)).real
        vort = jnp.sqrt(wx ** 2 + wy ** 2 + wz ** 2)
        return uh, vort

    _, frames = jax.lax.scan(frame, uh, None, length=cfg.nt_frames)
    return chi, jnp.moveaxis(frames, 0, -1)  # [n,n,n,nt]


def simulate_task(center_tuple, n: int = 32, nt: int = 8):
    """Top-level picklable entry for the cloud batch API."""
    cfg = NSConfig(n=n, nt_frames=nt)
    chi, vort = jax.jit(lambda c: simulate(c, cfg))(jnp.asarray(center_tuple, jnp.float32))
    return np.asarray(chi), np.asarray(vort)
