"""Two-phase (CO2/brine) porous-media flow — the OPM stand-in (paper §V-B).

IMPES on a regular 3-D grid: implicit incompressible pressure (variable-
coefficient 7-point stencil solved with matrix-free CG), explicit upwind
saturation transport with Corey relative permeabilities, buoyancy (CO2
rises), and rate-controlled injection wells. The geomodel generator makes
Sleipner-like layered permeability (high-perm sands separated by thin
shale barriers) so plumes pond under barriers and migrate up-dip, which is
the qualitative behaviour the paper's FNO learns.

Inputs/outputs mirror the paper: input = binary map of injector cells
(repeated along t by the data pipeline); output = CO2 saturation history
[nx, ny, nz, nt].
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TwoPhaseConfig:
    grid: Tuple[int, int, int] = (32, 16, 8)   # (nx, ny, nz), z down
    nt_frames: int = 8
    dt_frame: float = 30.0       # days per output frame
    substeps: int = 10
    mu_w: float = 1.0            # brine viscosity (cP)
    mu_n: float = 0.07           # CO2 viscosity
    swc: float = 0.1             # connate water
    snr: float = 0.05            # residual CO2
    # Buoyancy face-velocity scale. CFL bound: |v| dt_sub / phi < 1 with
    # dt_sub = dt_frame/substeps = 3 days, phi ~ 0.2 -> |v| << 0.067.
    # The face velocity is gravity * min(lam_z, gravity_lam_cap), so the cap
    # keeps buoyant velocity CFL-stable as CO2 mobility (1/mu_n ~ 14) and
    # permeability grow along the plume.
    gravity: float = 0.02
    gravity_lam_cap: float = 1.0
    inj_rate: float = 0.02       # total injected volume per day (scaled)
    cg_tol: float = 1e-6
    cg_iters: int = 200
    seed: int = 0


def make_geomodel(cfg: TwoPhaseConfig, seed: int = 0):
    """Layered lognormal permeability + thin low-perm barriers; porosity."""
    rng = np.random.default_rng(seed)
    nx, ny, nz = cfg.grid
    base = rng.lognormal(mean=0.0, sigma=0.4, size=(nx, ny, nz))
    layers = np.exp(0.8 * np.sin(np.linspace(0, 3 * np.pi, nz)))[None, None, :]
    k = base * layers
    for zb in range(2, nz, 3):  # shale streaks every ~3 cells
        k[:, :, zb] *= 0.05
    phi = 0.2 + 0.05 * (k / k.max())
    return jnp.asarray(k, jnp.float32), jnp.asarray(phi, jnp.float32)


def _harmonic_face_perm(k):
    """Harmonic mean transmissibilities on interior faces."""
    hx = 2 * k[1:] * k[:-1] / (k[1:] + k[:-1] + 1e-30)
    hy = 2 * k[:, 1:] * k[:, :-1] / (k[:, 1:] + k[:, :-1] + 1e-30)
    hz = 2 * k[:, :, 1:] * k[:, :, :-1] / (k[:, :, 1:] + k[:, :, :-1] + 1e-30)
    return hx, hy, hz


def _rel_perms(s, cfg):
    """Corey curves. s = CO2 (non-wetting) saturation."""
    se = jnp.clip((s - cfg.snr) / (1 - cfg.swc - cfg.snr), 0.0, 1.0)
    krn = se ** 2
    krw = (1 - se) ** 2
    return krw, krn


def _mobility(s, cfg):
    krw, krn = _rel_perms(s, cfg)
    return krw / cfg.mu_w + krn / cfg.mu_n


def _pressure_matvec(p, lam_face, cfg):
    """A p = -div(lam K grad p) with no-flow boundaries."""
    lx, ly, lz = lam_face
    out = jnp.zeros_like(p)
    fx = lx * (p[1:] - p[:-1])
    out = out.at[:-1].add(fx).at[1:].add(-fx)
    fy = ly * (p[:, 1:] - p[:, :-1])
    out = out.at[:, :-1].add(fy).at[:, 1:].add(-fy)
    fz = lz * (p[:, :, 1:] - p[:, :, :-1])
    out = out.at[:, :, :-1].add(fz).at[:, :, 1:].add(-fz)
    return -out + 1e-6 * p  # tiny regularization pins the nullspace


def _solve_pressure(s, k_faces, q, cfg):
    lamc = _mobility(s, cfg)
    lx = k_faces[0] * 0.5 * (lamc[1:] + lamc[:-1])
    ly = k_faces[1] * 0.5 * (lamc[:, 1:] + lamc[:, :-1])
    lz = k_faces[2] * 0.5 * (lamc[:, :, 1:] + lamc[:, :, :-1])
    lam_face = (lx, ly, lz)
    p, _ = jax.scipy.sparse.linalg.cg(
        lambda x: _pressure_matvec(x, lam_face, cfg),
        q,
        tol=cfg.cg_tol,
        maxiter=cfg.cg_iters,
    )
    return p, lam_face


def _upwind_flux(p, s, lam_face, cfg):
    """CO2 mass flux with phase upwinding + gravity segregation (z up-flux)."""
    def frac_flow(sv):
        krw, krn = _rel_perms(sv, cfg)
        mw, mn = krw / cfg.mu_w, krn / cfg.mu_n
        return mn / (mw + mn + 1e-12)

    def face_flux(pm, sp, sm, lam, grav=0.0):
        v = -lam * (pm) + grav  # total velocity at face (+ gravity term)
        f_up = jnp.where(v > 0, frac_flow(sm), frac_flow(sp))
        return f_up * v

    # div(c) accumulates +F for the face (c, c+1) (flux positive toward
    # c+1 leaves cell c) and -F at c+1.
    out = jnp.zeros_like(s)
    fx = face_flux(p[1:] - p[:-1], s[1:], s[:-1], lam_face[0])
    out = out.at[:-1].add(fx).at[1:].add(-fx)
    fy = face_flux(p[:, 1:] - p[:, :-1], s[:, 1:], s[:, :-1], lam_face[1])
    out = out.at[:, :-1].add(fy).at[:, 1:].add(-fy)
    # z: gravity drives CO2 upward (toward smaller z index = shallower)
    gterm = -cfg.gravity * jnp.minimum(lam_face[2], cfg.gravity_lam_cap)
    fz = face_flux(p[:, :, 1:] - p[:, :, :-1], s[:, :, 1:], s[:, :, :-1], lam_face[2], grav=gterm)
    out = out.at[:, :, :-1].add(fz).at[:, :, 1:].add(-fz)
    return out


def simulate(
    well_mask: jnp.ndarray, cfg: TwoPhaseConfig = TwoPhaseConfig(), seed: int = 0
) -> jnp.ndarray:
    """well_mask: [nx,ny,nz] binary injector cells -> saturation [*, nt]."""
    k, phi = make_geomodel(cfg, seed)
    k_faces = _harmonic_face_perm(k)
    n_wells = jnp.maximum(jnp.sum(well_mask), 1.0)
    q = well_mask * cfg.inj_rate / n_wells  # injection source
    q = q - jnp.mean(q)                     # closed box: balance sources
    dt = cfg.dt_frame / cfg.substeps

    def substep(s, _):
        p, lam_face = _solve_pressure(s, k_faces, q, cfg)
        div = _upwind_flux(p, s, lam_face, cfg)
        src = jnp.where(well_mask > 0, cfg.inj_rate / n_wells, 0.0)
        s_new = s + dt * (src - div) / phi
        return jnp.clip(s_new, 0.0, 1.0 - cfg.swc), None

    def frame(s, _):
        s, _ = jax.lax.scan(substep, s, None, length=cfg.substeps)
        return s, s

    s0 = jnp.zeros(cfg.grid, jnp.float32)
    _, frames = jax.lax.scan(frame, s0, None, length=cfg.nt_frames)
    return jnp.moveaxis(frames, 0, -1)  # [nx,ny,nz,nt]


def random_well_mask(cfg: TwoPhaseConfig, n_wells: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    nx, ny, nz = cfg.grid
    mask = np.zeros(cfg.grid, np.float32)
    for _ in range(n_wells):
        i = rng.integers(2, nx - 2)
        j = rng.integers(2, ny - 2)
        mask[i, j, nz - 3 :] = 1.0  # perforate near the bottom
    return mask


def simulate_task(seed: int, n_wells: int = 2, grid=(32, 16, 8), nt: int = 8):
    """Top-level picklable entry for the cloud batch API."""
    cfg = TwoPhaseConfig(grid=tuple(grid), nt_frames=nt)
    mask = random_well_mask(cfg, n_wells, seed)
    sat = jax.jit(lambda m: simulate(m, cfg, seed=0))(jnp.asarray(mask))
    return mask, np.asarray(sat)
