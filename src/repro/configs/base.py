"""Architecture & shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact numbers from the
assignment brief), plus ``ShapeConfig`` for the four assigned input shapes.
``input_specs(arch, shape)`` produces ShapeDtypeStruct stand-ins for the
dry-run (no allocation); smoke tests use ``reduced()`` configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.rglru import RGLRUConfig


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    dh_nope: int = 128
    dh_rope: int = 64
    dh_v: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder; the conv/mel frontend is a stub: input_specs
    provides precomputed frame embeddings [b, frames, d_model]."""
    n_layers: int = 4
    frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    window: Optional[int] = None      # sliding-window local attention
    mlp_act: str = "swiglu"
    embed_scale: bool = False         # gemma: x *= sqrt(d)
    norm: str = "rms"                 # rms | ln
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    block_pattern: Tuple[str, ...] = ()   # hybrid pattern, e.g. (rec, rec, attn)
    encoder: Optional[EncoderConfig] = None
    dtype: str = "bfloat16"
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    # -- capability flags for the assigned shape grid ---------------------
    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k is feasible (SSM / hybrid with bounded window)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all 10 assigned archs have a decode path

    def approx_params(self) -> int:
        """Analytic parameter count (for 6ND roofline term)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embed
        total += v * d  # lm_head
        hd = self.head_dim_
        for kind in self.layer_kinds():
            if kind == "ssm":
                s = self.ssm
                di = s.d_inner(d)
                h = s.n_heads(d)
                total += d * (2 * di + 2 * s.d_state + h)  # in_proj
                total += di * d + s.conv_dim(d) * s.conv_kernel + di
                continue
            if kind == "rec":
                w = self.rglru.width(d)
                total += 2 * d * w + 2 * w * w + w * d + 4 * w
                continue
            # attention
            if self.mla is not None:
                m = self.mla
                total += d * self.n_heads * (m.dh_nope + m.dh_rope)
                total += d * (m.kv_lora + m.dh_rope)
                total += m.kv_lora * self.n_heads * (m.dh_nope + m.dh_v)
                total += self.n_heads * m.dh_v * d
            else:
                total += d * self.n_heads * hd + 2 * d * self.kv_heads * hd
                total += self.n_heads * hd * d
            # mlp
            if kind == "moe":
                mo = self.moe
                total += d * mo.n_experts  # router
                total += mo.n_experts * 3 * d * mo.d_expert
                total += mo.n_shared * 3 * d * mo.d_expert
            elif kind == "dense0":
                total += 3 * d * self.moe.first_dense_ff
            elif kind in ("dense", "attn"):
                mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                total += mult * d * self.d_ff
        return total

    def approx_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.approx_params()
        d = self.d_model
        mo = self.moe
        dense_total = self.approx_params()
        inactive = (mo.n_experts - mo.top_k) * 3 * d * mo.d_expert
        n_moe_layers = sum(1 for k in self.layer_kinds() if k == "moe")
        return dense_total - n_moe_layers * inactive

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind sequence."""
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        if self.family == "hybrid":
            pat = self.block_pattern or ("rec", "rec", "attn")
            kinds = []
            while len(kinds) < self.n_layers:
                kinds.extend(pat)
            return tuple(kinds[: self.n_layers])
        if self.family == "moe":
            first = ("dense0",) if (self.moe and self.moe.first_dense_ff) else ("moe",)
            return first + ("moe",) * (self.n_layers - 1)
        if self.family == "encdec":
            return ("dense",) * self.n_layers
        return ("dense",) * self.n_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch x shape) dry-run cell runs, and why not if not."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: O(S^2) at 524k ctx — skipped per assignment"
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        if arch.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, arch.encoder.frames, arch.d_model), jnp.dtype(arch.dtype)
            )
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if arch.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, arch.encoder.frames, arch.d_model), jnp.dtype(arch.dtype)
            )
        return specs
    # decode: one token per sequence + cache of length seq_len
    specs = {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "index": jax.ShapeDtypeStruct((), i32),
    }
    return specs
