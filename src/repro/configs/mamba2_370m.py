"""mamba2-370m [ssm] — arXiv:2405.21060 (unverified tier).

48L d_model=1024 (attention-free) d_ff=0 vocab=50280, ssm_state=128.
SSD (state-space duality) blocks; d_inner=2048, head_dim=64 -> 32 heads.
"""
from repro.configs.base import ArchConfig
from repro.models.ssm import SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,     # d_inner / head_dim (informational; SSM derives its own)
    kv_heads=32,
    d_ff=0,
    vocab=50280,
    # chunk=128: the SSD intra-chunk decay tensor is O(b*s*chunk*h) — 128
    # halves it vs 256 while keeping (128 x N)x(N x 128) MXU-aligned matmuls.
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=128),
    notes="attention-free; long_500k runs with O(1) recurrent state",
)
