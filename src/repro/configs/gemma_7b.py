"""gemma-7b [dense] — arXiv:2403.08295 (hf-verified tier).

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000; GeGLU; head_dim=256;
embeddings scaled by sqrt(d). (The 2b sibling uses MQA; 7b is full MHA.)
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    mlp_act="geglu",
    embed_scale=True,
)
