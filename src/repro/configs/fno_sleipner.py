"""The paper's Sleipner CO2-flow FNO (§V-B, CCS benchmark).

Paper grid 262x118x64 x 86 time steps padded to 256x128x64x88 (the original
2.1M-cell simulation grid, mesh-divisible). Inputs: binary injection-well
map (repeated along t); outputs: CO2 saturation history.
"""
from repro.core.fno import FNOConfig

CONFIG = FNOConfig(
    grid=(256, 128, 64, 88),
    modes=(24, 16, 8, 10),
    width=40,
    in_channels=1,
    out_channels=1,
    n_blocks=4,
    decoder_dim=128,
)

SHAPES = (
    ("train_b32", 32, "train"),
    ("infer_b32", 32, "infer"),
)
