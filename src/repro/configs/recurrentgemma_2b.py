"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (hf-verified tier).

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000; Griffin pattern
(rec, rec, attn) — RG-LRU recurrent blocks + local sliding-window (2048)
attention, head_dim=256; GeGLU MLP after every temporal block.
"""
from repro.configs.base import ArchConfig
from repro.models.rglru import RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    window=2048,
    mlp_act="geglu",
    embed_scale=True,
    rglru=RGLRUConfig(d_rnn=2560, conv_kernel=4),
    block_pattern=("rec", "rec", "attn"),
    notes="long_500k runs: window-bounded KV + O(1) RG-LRU state",
)
