"""Architecture registry: the 10 assigned archs + the paper's own FNOs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    EncoderConfig,
    LM_SHAPES,
    MLAConfig,
    ShapeConfig,
    cell_supported,
    get_shape,
    input_specs,
)

ARCH_IDS = (
    "deepseek-moe-16b",
    "deepseek-v2-lite-16b",
    "mamba2-370m",
    "whisper-tiny",
    "chameleon-34b",
    "qwen1.5-32b",
    "chatglm3-6b",
    "gemma-7b",
    "minitron-8b",
    "recurrentgemma-2b",
)

FNO_IDS = ("fno-ns3d", "fno-sleipner", "fno-sleipner-2d")

_MODULES = {arch_id: arch_id.replace("-", "_").replace(".", "_") for arch_id in ARCH_IDS}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def _fno_module(name: str):
    if name not in FNO_IDS:
        raise KeyError(f"unknown FNO config {name!r}")
    return importlib.import_module(f"repro.configs.{name.replace('-', '_')}")


def get_fno(name: str):
    mod = _fno_module(name)
    return mod.CONFIG, mod.SHAPES


def get_fno_model_axes(name: str):
    """Model-parallel layout for an FNO config: (model_axis, pencil_shape).

    1-D configs return ("model", None); pencil configs declare MODEL_AXES
    (e.g. ("mx", "my")) and PENCIL_SHAPE (e.g. (8, 4)) in their module.
    """
    mod = _fno_module(name)
    return getattr(mod, "MODEL_AXES", "model"), getattr(mod, "PENCIL_SHAPE", None)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    from repro.models.moe import MoEConfig
    from repro.models.ssm import SSMConfig
    from repro.models.rglru import RGLRUConfig

    changes = dict(
        n_layers=3 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4,
        kv_heads=max(1, min(cfg.kv_heads, 2)),
        d_ff=0 if cfg.family == "ssm" else 128,
        vocab=512,
        head_dim=16,
        window=16 if cfg.window else None,
    )
    if cfg.moe:
        changes["moe"] = MoEConfig(
            n_experts=8,
            top_k=2,
            d_expert=32,
            n_shared=cfg.moe.n_shared and 1,
            first_dense_ff=64 if cfg.moe.first_dense_ff else 0,
            norm_topk=cfg.moe.norm_topk,
        )
    if cfg.mla:
        changes["mla"] = MLAConfig(kv_lora=32, dh_nope=16, dh_rope=8, dh_v=16)
        changes["head_dim"] = None
    if cfg.ssm:
        changes["ssm"] = SSMConfig(d_state=16, head_dim=16, chunk=16)
        changes["head_dim"] = None
        changes["n_heads"] = 8
        changes["kv_heads"] = 8
    if cfg.rglru:
        changes["rglru"] = RGLRUConfig(d_rnn=0, conv_kernel=4)
    if cfg.encoder:
        changes["encoder"] = EncoderConfig(n_layers=2, frames=12)
    return dataclasses.replace(cfg, **changes)
