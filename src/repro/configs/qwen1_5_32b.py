"""qwen1.5-32b [dense] — hf:Qwen/Qwen1.5-32B family (hf-verified tier).

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064; QKV bias; SwiGLU;
rope theta 1e6.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    kv_heads=40,
    d_ff=27392,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
)
