"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434 (hf-verified tier).

27L d_model=2048 16H d_ff=1408 vocab=102400; MLA kv_lora=512 (decoupled
RoPE head 64, nope 128, v 128); MoE 64 routed top-6 + 2 shared; layer 0
dense (10944). The assignment line also mentions "160 routed" — that figure
belongs to full V2; v2-lite is 64 routed (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, MLAConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=1408,
    vocab=102400,
    rope_theta=10000.0,
    mlp_act="swiglu",
    mla=MLAConfig(kv_lora=512, dh_nope=128, dh_rope=64, dh_v=128),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        first_dense_ff=10944,
        norm_topk=True,
    ),
    notes="MLA latent KV cache: 576 B-equiv/token vs 4096 for GQA",
)
