"""minitron-8b [dense] — arXiv:2407.14679 (hf-verified tier).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000; pruned nemotron:
squared-ReLU non-gated MLP, rope partial per nemotron (fraction 0.5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=16384,
    vocab=256000,
    head_dim=128,
    rope_fraction=0.5,
    mlp_act="relu2",
)
