"""chameleon-34b [vlm] — arXiv:2405.09818 (unverified tier).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (incl. VQ image
tokens). Early-fusion: image tokens are ordinary vocabulary entries, so the
backbone is a dense decoder; the VQ tokenizer frontend is a stub (token ids
arrive pre-fused). QK-norm per the chameleon recipe.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=22016,
    vocab=65536,
    head_dim=128,
    qk_norm=True,
    mlp_act="swiglu",
    notes="early-fusion VQ image tokens; frontend stubbed as token ids",
)
