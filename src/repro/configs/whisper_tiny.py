"""whisper-tiny [audio] — arXiv:2212.04356 (unverified tier).

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865; encoder-decoder with conv
frontend STUBBED (input_specs provides precomputed frame embeddings,
1500 frames). LayerNorm + GELU + sinusoidal positions.
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    kv_heads=6,
    d_ff=1536,
    vocab=51865,
    qkv_bias=True,
    rope_fraction=0.0,   # sinusoidal positions, no RoPE
    mlp_act="gelu",
    norm="ln",
    norm_eps=1e-5,
    encoder=EncoderConfig(n_layers=4, frames=1500),
    notes="frontend stub per assignment; decoder positions sinusoidal",
)
