"""The paper's Navier-Stokes FNO (turbulent flow around a sphere, §V-A).

Paper grid 130x130x130x64 padded to 128^3 x 64 (mesh-divisible; the serial
oracle supports arbitrary grids). ~20-25% of modes kept per dim (paper: "we
truncated around 80 percent of the frequencies in each dimension"); 2*m_y
must divide the 16-way model axis, hence m_y=16.
"""
from repro.core.fno import FNOConfig

CONFIG = FNOConfig(
    grid=(128, 128, 128, 64),
    modes=(16, 16, 16, 8),
    width=40,
    in_channels=1,   # binary sphere map, repeated along t
    out_channels=1,  # vorticity
    n_blocks=4,
    decoder_dim=128,
)

# (name, global_batch, kind) — batches divide the 32-way (pod x data) axes
SHAPES = (
    ("train_b32", 32, "train"),
    ("infer_b32", 32, "infer"),
)
