"""Sleipner CO2-flow FNO on a 2-D pencil-decomposed ("mx", "my") mesh.

Same physics/grid as ``fno_sleipner`` (256x128x64 x 88), but the solution
tensor is sharded along BOTH x and y. The 1-D Alg. 2 decomposition caps
model parallelism at min(nx, 2*my) = 32 devices for this grid; the pencil
constraints (Px | nx, Px | 2my, Py | ny, Py | 2mz) allow Px*Py up to
32 * 16 = 512 model shards — enough to spread the 2.1M-cell Sleipner
solution over a full pod.
"""
from repro.core.fno import FNOConfig

CONFIG = FNOConfig(
    grid=(256, 128, 64, 88),
    modes=(24, 16, 8, 10),
    width=40,
    in_channels=1,
    out_channels=1,
    n_blocks=4,
    decoder_dim=128,
)

# Model-parallel mesh axes for make_dist_forward(model_axis=MODEL_AXES).
MODEL_AXES = ("mx", "my")

# Production pencil shape: 8 x-shards x 4 y-shards = 32-way model
# parallelism with headroom to 512 (vs the hard 32 cap of the 1-D path).
PENCIL_SHAPE = (8, 4)

SHAPES = (
    ("train_b32", 32, "train"),
    ("infer_b32", 32, "infer"),
)
