"""chatglm3-6b [dense] — arXiv:2406.12793 (hf-verified tier).

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024; 2D-RoPE lineage:
rotary applied to half the head dim (rope_fraction=0.5); SwiGLU.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    kv_heads=2,
    d_ff=13696,
    vocab=65024,
    head_dim=128,
    rope_fraction=0.5,
    mlp_act="swiglu",
)
