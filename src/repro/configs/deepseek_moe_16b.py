"""deepseek-moe-16b [moe] — arXiv:2401.06066 (hf-verified tier).

28L d_model=2048 16H (MHA: kv=16) d_ff=1408 (per fine-grained expert)
vocab=102400; 2 shared + 64 routed experts, top-6; layer 0 dense
(first_dense_ff=10944 per the HF config).
"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    rope_theta=10000.0,
    mlp_act="swiglu",
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        first_dense_ff=10944,
        norm_topk=False,
    ),
    notes="fine-grained experts (1/4 width), 2 shared always-on",
)
