"""Multi-replica serving gateway: one front door, N scheduler replicas.

The paper's payoff is a surrogate that serves commercial-scale scenario
workloads orders of magnitude faster than the numerical simulator — at
production traffic that is a FLEET problem, not a scheduler problem. One
``Scheduler`` drives one (data x model) serving mesh; this module is the
front-end above it (the shape of rtp-llm's flexlb master/worker balancer):
requests enter through ``Gateway.submit`` and are ROUTED to one of N
independent replicas, each its own ``ModelRunner`` + ``Scheduler`` (and in
production its own host / mesh slice — replicas may be heterogeneous in
model-shard layout, slot count, or even checkpoint).

Routing policies (``policy=``):

  * ``least-pending`` (default) — backlog-aware: the replica with the
    fewest unfinished requests (queued + active + dedup followers, the
    slot-pool stats the scheduler already tracks) wins; deterministic
    index tie-break.
  * ``round-robin`` — cyclic, backlog-blind (the contrast baseline).
  * ``affinity``    — cache-affinity: requests whose runner reports an
    ``affinity_key`` (the geomodel content hash for FNO serving) stick to
    the replica that first served that key, so per-replica
    ``GeomodelCache`` hit-rates match the single-process rate and
    byte-identical duplicates still dedup onto one slot; a first-seen key
    is placed to balance pinned keys across the fleet (backlog as the
    tie-break), keyless requests fall back to least-pending.

Request-level priority/deadline policy lives in the scheduler (``priority``
/ ``deadline_s`` request attributes) and therefore applies per replica;
the gateway only places requests.

Health and failover: a replica whose runner RAISES out of a scheduler step
is marked unhealthy and drained — its unfinished requests (queued, active,
followers) are reset (partial rollout outputs dropped) and re-routed to
healthy replicas, keeping their original ``submitted_s`` so end-to-end
latency stays honest. One broken replica cannot wedge the fleet; if no
healthy replica remains the orphans are marked failed (``Gateway.failed``)
rather than lost. Per-request admission errors stay request-level, exactly
as in a lone scheduler.

Autoscaling hook: given a ``replica_factory``, the gateway spawns a
replica when mean backlog per healthy replica crosses
``scale_up_backlog`` and retires an idle one when it falls to
``scale_down_backlog`` (within ``[min_replicas, max_replicas]``); scale
events are recorded in ``Gateway.scale_events``. The factory is also the
self-healing path: a failed replica below ``min_replicas`` is replaced.

``serve_open_loop`` drives an open-loop arrival process (arrivals do not
wait for completions) through the fleet on a measured event clock: every
tick runs the REAL scheduler/runner — real routing, admission, compute,
outputs — and its measured wall time becomes the tick's service time on
the virtual timeline. ``per_replica_executors=True`` lets replica service
times overlap, which is the deployment model (each replica is its own
serving host); ``False`` serializes all ticks on one executor — what this
single host can actually do. CI machines are single-core, so fleet
concurrency is accounted on the event clock rather than wall time — the
same precedent as the HLO async-collective overlap accounting, which is
tested on synthetic HLO until a real-ICI run exists.

With one replica and the default policy the gateway is a pass-through:
the lone scheduler sees the identical submission order and tick cadence,
so single-replica serving stays bit-identical to pre-gateway serving.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, List, Optional, Sequence

from repro.serve.scheduler import Scheduler

POLICIES = ("least-pending", "round-robin", "affinity")


class ReplicaHandle:
    """One serving replica: a runner + its scheduler + health/route stats."""

    def __init__(self, index: int, runner, *, max_slots: Optional[int] = None,
                 dedup: bool = True):
        self.index = index
        self.name = f"r{index}"
        self.runner = runner
        self.sched = Scheduler(
            runner, max_slots or getattr(runner, "max_slots", 4), dedup=dedup
        )
        self.healthy = True
        self.error: Optional[Exception] = None
        self.routed = 0
        self._failed_over = False
        # how much of sched.finished/.failed the gateway has collected
        self._collected_f = 0
        self._collected_x = 0

    def pending(self) -> int:
        return self.sched.pending()

    def tick(self) -> int:
        """One scheduler step. A raising runner marks the replica unhealthy
        (request-level admission errors do NOT — the scheduler already
        contains those per-request)."""
        if not self.healthy:
            return 0
        try:
            return self.sched.step()
        except Exception as exc:  # noqa: BLE001 — any runner/step failure
            self.healthy = False
            self.error = exc
            return 0


class Gateway:
    """Load-balancing front-end over N independent scheduler replicas."""

    def __init__(
        self,
        runners: Optional[Sequence] = None,
        *,
        policy: str = "least-pending",
        replica_factory: Optional[Callable[[], object]] = None,
        min_replicas: int = 1,
        max_replicas: int = 8,
        scale_up_backlog: Optional[int] = None,
        scale_down_backlog: int = 0,
        max_slots: Optional[int] = None,
        dedup: bool = True,
    ):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if min_replicas < 1 or max_replicas < min_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"{min_replicas}/{max_replicas}"
            )
        runners = list(runners or [])
        if not runners:
            if replica_factory is None:
                raise ValueError("need runners and/or a replica_factory")
            runners = [replica_factory() for _ in range(min_replicas)]
        if len(set(map(id, runners))) != len(runners):
            raise ValueError(
                "each replica needs its own runner instance (slot state "
                "is per-runner; one runner cannot back two schedulers)"
            )
        self.policy = policy
        self.replica_factory = replica_factory
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.scale_up_backlog = scale_up_backlog
        self.scale_down_backlog = scale_down_backlog
        self._max_slots = max_slots
        self._dedup = dedup
        self.replicas: List[ReplicaHandle] = [
            ReplicaHandle(i, r, max_slots=max_slots, dedup=dedup)
            for i, r in enumerate(runners)
        ]
        self._next_index = len(self.replicas)
        self.retired: List[ReplicaHandle] = []
        self._rr = 0
        self._affinity: dict = {}
        self.finished: list = []
        self.failed: list = []
        self.scale_events: list = []
        self.ticks = 0
        self.rerouted = 0

    # -- routing -------------------------------------------------------------
    def healthy_replicas(self) -> List[ReplicaHandle]:
        return [r for r in self.replicas if r.healthy]

    def _least_pending(self, pool: List[ReplicaHandle]) -> ReplicaHandle:
        return min(pool, key=lambda r: (r.pending(), r.index))

    def _pin_target(self, pool: List[ReplicaHandle]) -> ReplicaHandle:
        """Placement for a first-seen affinity key: balance pinned keys
        across replicas before backlog, so distinct geomodels spread over
        the fleet even when every replica is idle (a pure least-pending
        fallback would pin every key to replica 0 under light load)."""
        pins: dict = {}
        for r in self._affinity.values():
            pins[id(r)] = pins.get(id(r), 0) + 1
        return min(pool, key=lambda r: (pins.get(id(r), 0), r.pending(), r.index))

    def route(self, request) -> ReplicaHandle:
        """Pick the replica for ``request`` (does not submit)."""
        pool = self.healthy_replicas()
        if not pool:
            errs = "; ".join(
                f"{r.name}: {r.error}" for r in self.replicas if r.error
            )
            raise RuntimeError(f"no healthy replicas ({errs or 'none spawned'})")
        if self.policy == "affinity":
            key_fn = getattr(pool[0].runner, "affinity_key", None)
            key = key_fn(request) if key_fn is not None else None
            if key is not None:
                sticky = self._affinity.get(key)
                if sticky is not None and sticky.healthy and sticky in self.replicas:
                    return sticky
                chosen = self._pin_target(pool)
                self._affinity[key] = chosen
                return chosen
            return self._least_pending(pool)
        if self.policy == "round-robin":
            chosen = pool[self._rr % len(pool)]
            self._rr += 1
            return chosen
        return self._least_pending(pool)

    def submit(self, request) -> ReplicaHandle:
        """Route and enqueue one request; returns the chosen replica."""
        replica = self.route(request)
        replica.routed += 1
        replica.sched.submit(request)
        return replica

    # -- drive loop ----------------------------------------------------------
    def has_work(self) -> bool:
        return any(r.healthy and r.sched.has_work() for r in self.replicas)

    def pending(self) -> int:
        return sum(r.pending() for r in self.healthy_replicas())

    def tick(self) -> int:
        """One fleet round: a scheduler step on every healthy replica with
        work, failover for replicas that broke this round, collection of
        newly finished/failed requests, then the autoscale check. Returns
        the number of slots active across the fleet."""
        n_active = 0
        for replica in list(self.replicas):
            if replica.healthy and replica.sched.has_work():
                n_active += replica.tick()
            if not replica.healthy and not replica._failed_over:
                self._failover(replica)
        self._collect()
        self._autoscale()
        self.ticks += 1
        return n_active

    def tick_replica(self, replica: ReplicaHandle) -> int:
        """One step on a single replica plus the same bookkeeping
        ``tick`` does fleet-wide — the open-loop driver's granularity."""
        n_active = replica.tick()
        if not replica.healthy and not replica._failed_over:
            self._failover(replica)
        self._collect()
        self._autoscale()
        self.ticks += 1
        return n_active

    def run_until_done(self, max_steps: int = 1000) -> list:
        """Drive fleet rounds until every replica drains. ``max_steps``
        budgets this call (same per-call semantics as the scheduler)."""
        start = self.ticks
        while self.has_work() and self.ticks - start < max_steps:
            self.tick()
        if self.has_work():
            warnings.warn(
                f"Gateway.run_until_done: max_steps={max_steps} exhausted "
                f"with {self.pending()} request(s) still queued/active "
                f"({len(self.finished)} finished, {len(self.failed)} "
                f"failed) — raise max_steps",
                RuntimeWarning,
                stacklevel=2,
            )
        return self.finished

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        """Per-replica backlog/health/cache plus fleet aggregates (the
        fleet cache hit-rate sums hits/lookups over every replica's
        runner cache, including retired/unhealthy ones)."""
        replicas = []
        hits = misses = dedup = cache_bytes = 0
        store = None
        for r in self.replicas + self.retired:
            cache = getattr(r.runner, "cache", None)
            cs = cache.stats if cache is not None else None
            if cs is not None:
                hits += cs["hits"]
                misses += cs["misses"]
                cache_bytes += cs["bytes"]
            if store is None:
                store = getattr(r.runner, "cache_store", None)
            dedup += r.sched.dedup_attached
            replicas.append({
                "name": r.name,
                "healthy": r.healthy,
                "retired": r in self.retired,
                "pending": r.pending(),
                "routed": r.routed,
                "finished": len(r.sched.finished),
                "failed": len(r.sched.failed),
                "dedup_attached": r.sched.dedup_attached,
                "cache": cs,
                "error": repr(r.error) if r.error is not None else None,
            })
        lookups = hits + misses
        return {
            "replicas": replicas,
            "fleet": {
                "n_replicas": len(self.replicas),
                "n_healthy": len(self.healthy_replicas()),
                "pending": self.pending(),
                "finished": len(self.finished),
                "failed": len(self.failed),
                "dedup_attached": dedup,
                "cache_hits": hits,
                "cache_misses": misses,
                "cache_hit_rate": hits / lookups if lookups else 0.0,
                "cache_bytes": cache_bytes,
                # replicas share one store instance; report it once
                "store": store.stats if store is not None else None,
                "rerouted": self.rerouted,
                "scale_events": list(self.scale_events),
                "ticks": self.ticks,
            },
        }

    # -- internals -----------------------------------------------------------
    def _collect(self) -> None:
        for r in self.replicas:
            self._collect_replica(r)

    def _collect_replica(self, r: ReplicaHandle) -> None:
        sched = r.sched
        if len(sched.finished) > r._collected_f:
            self.finished.extend(sched.finished[r._collected_f:])
            r._collected_f = len(sched.finished)
        if len(sched.failed) > r._collected_x:
            self.failed.extend(sched.failed[r._collected_x:])
            r._collected_x = len(sched.failed)

    def _spawn(self) -> ReplicaHandle:
        replica = ReplicaHandle(
            self._next_index, self.replica_factory(),
            max_slots=self._max_slots, dedup=self._dedup,
        )
        self._next_index += 1
        self.replicas.append(replica)
        return replica

    def _retire(self, replica: ReplicaHandle) -> None:
        self._collect_replica(replica)
        self.replicas.remove(replica)
        self.retired.append(replica)
        self._affinity = {
            k: v for k, v in self._affinity.items() if v is not replica
        }

    def _failover(self, replica: ReplicaHandle) -> None:
        """Drain a broken replica and re-route its unfinished requests;
        spawn a replacement if a factory keeps the fleet below minimum."""
        replica._failed_over = True
        self._collect_replica(replica)
        orphans = replica.sched.drain_unfinished()
        self._affinity = {
            k: v for k, v in self._affinity.items() if v is not replica
        }
        if (
            self.replica_factory is not None
            and len(self.healthy_replicas()) < self.min_replicas
            and len(self.replicas) < self.max_replicas + 1
        ):
            self._spawn()
            self.scale_events.append((self.ticks, "heal", len(self.replicas)))
        for request in orphans:
            submitted0 = getattr(request, "submitted_s", None)
            try:
                target = self.route(request)
            except RuntimeError as exc:
                request.error = RuntimeError(
                    f"replica {replica.name} failed mid-flight "
                    f"({replica.error!r}) and no healthy replica remains"
                )
                request.error.__cause__ = exc
                request.done = True
                request.finished_s = time.perf_counter()
                self.failed.append(request)
                continue
            reset = getattr(target.runner, "reset", None)
            if reset is not None:
                reset(request)
            target.routed += 1
            target.sched.submit(request)
            if submitted0 is not None:
                # end-to-end latency counts from the FIRST submission
                request.submitted_s = submitted0
            self.rerouted += 1

    def _autoscale(self) -> None:
        if self.replica_factory is None or self.scale_up_backlog is None:
            return
        pool = self.healthy_replicas()
        if not pool:
            return
        backlog_per_replica = sum(r.pending() for r in pool) / len(pool)
        if (
            backlog_per_replica > self.scale_up_backlog
            and len(pool) < self.max_replicas
        ):
            self._spawn()
            self.scale_events.append((self.ticks, "up", len(self.replicas)))
        elif (
            backlog_per_replica <= self.scale_down_backlog
            and len(pool) > self.min_replicas
        ):
            idle = [r for r in pool if r.pending() == 0]
            if idle:
                self._retire(idle[-1])
                self.scale_events.append(
                    (self.ticks, "down", len(self.replicas))
                )


@dataclasses.dataclass
class OpenLoopReport:
    """Result of one open-loop pass: virtual-clock throughput + latency."""

    n_served: int
    n_failed: int
    makespan_s: float
    latencies_s: list  # sorted, per served request: finish - arrival
    ticks: int

    @property
    def scen_per_s(self) -> float:
        return self.n_served / self.makespan_s if self.makespan_s > 0 else 0.0

    def percentile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        i = min(len(self.latencies_s) - 1, int(len(self.latencies_s) * q))
        return self.latencies_s[i]


def serve_open_loop(
    gateway: Gateway,
    requests: Sequence,
    arrivals_s: Sequence[float],
    *,
    per_replica_executors: bool = True,
    max_ticks: int = 100000,
) -> OpenLoopReport:
    """Drive an open-loop arrival schedule through the fleet on a measured
    event clock (see module docstring). ``arrivals_s`` are nondecreasing
    arrival offsets, one per request; arrivals never wait for completions.
    Every tick executes the real scheduler/runner and its measured wall
    time advances the owning executor's clock — one executor per replica
    (deployment model) or one shared executor (this host)."""
    if len(requests) != len(arrivals_s):
        raise ValueError(
            f"{len(requests)} requests vs {len(arrivals_s)} arrival times"
        )
    if any(b < a for a, b in zip(arrivals_s, arrivals_s[1:])):
        raise ValueError("arrivals_s must be nondecreasing")
    free_at: dict = {}
    shared_free = 0.0  # single-executor timeline
    last_ticked: dict = {}  # fairness tie-break when starts are equal
    i = 0
    n = len(requests)
    finish_times: list = []
    ticks = 0

    def start_of(replica) -> float:
        if per_replica_executors:
            return free_at.get(id(replica), 0.0)
        return shared_free

    while ticks < max_ticks:
        pool = [
            r for r in gateway.replicas if r.healthy and r.sched.has_work()
        ]
        next_tick = min(
            (
                (start_of(r), last_ticked.get(id(r), -1), r.index, r)
                for r in pool
            ),
            default=None,
        )
        if i < n and (next_tick is None or arrivals_s[i] <= next_tick[0]):
            t_arr = arrivals_s[i]
            request = requests[i]
            request._arrived_v = t_arr
            try:
                target = gateway.submit(request)
            except RuntimeError as exc:  # no healthy replica at all
                request.error = exc
                request.done = True
                request._finished_v = t_arr
                gateway.failed.append(request)
                i += 1
                continue
            # an executor that went idle before the arrival can only start
            # again at the arrival; a busy one keeps its own timeline
            if per_replica_executors:
                free_at[id(target)] = max(start_of(target), t_arr)
            else:
                shared_free = max(shared_free, t_arr)
            i += 1
            continue
        if next_tick is None:
            break  # no arrivals left, fleet drained (or all replicas dead)
        t0, _, _, replica = next_tick
        last_ticked[id(replica)] = ticks
        sched = replica.sched
        before_f, before_x = len(sched.finished), len(sched.failed)
        wall0 = time.perf_counter()
        gateway.tick_replica(replica)
        service_s = time.perf_counter() - wall0
        t_end = t0 + service_s
        if per_replica_executors:
            free_at[id(replica)] = t_end
        else:
            shared_free = t_end
        for request in (
            list(sched.finished[before_f:]) + list(sched.failed[before_x:])
        ):
            request._finished_v = t_end
            finish_times.append(t_end)
        ticks += 1

    latencies = sorted(
        r._finished_v - r._arrived_v
        for r in requests
        if getattr(r, "_finished_v", None) is not None
        and getattr(r, "error", None) is None
    )
    n_failed = sum(1 for r in requests if getattr(r, "error", None) is not None)
    makespan = max(finish_times) - min(arrivals_s) if finish_times else 0.0
    return OpenLoopReport(
        n_served=len(latencies),
        n_failed=n_failed,
        makespan_s=makespan,
        latencies_s=latencies,
        ticks=ticks,
    )
