"""Fleet-shared geomodel cache store — the disaggregated tier behind the
per-replica ``GeomodelCache``.

Gateway replicas each keep a process-local LRU (``serve.geomodel_cache``),
but affinity routing re-pins a geomodel to a different replica after a
failover — and without a shared tier the new replica re-pays the full
static prefix (normalize + prelift + spectral prefix) that the failed
replica had already computed. This module is the serving-system pattern of
a disaggregated KV-cache store (rtp-llm's ``cache_store/``): a
content-hash-keyed, checkpoint-versioned store that replicas consult on
local miss and populate on fresh compute, so a geomodel warmed anywhere is
warm fleet-wide.

Two backends:

  * ``DictCacheStore`` — a shared in-process dict (replicas in one process,
    e.g. tests/benchmarks or threaded gateways); arrays are copied on both
    put and get so no caller can mutate a stored entry.
  * ``FileCacheStore`` — one ``.npz`` per (version, key) under a root
    directory; writes go to a temp file then ``os.replace`` so concurrent
    replica processes never observe a torn entry.

Versioning: entries are namespaced by a checkpoint+config signature
(``FNORunner.cache_version``) — a replica restored from a different
checkpoint, or configured with different modes/width, can never consume
another's intermediates.
"""
from __future__ import annotations

import os
import tempfile
import threading
from typing import Optional

import numpy as np

from repro.serve.geomodel_cache import LEVELS, GeomodelEntry

#: Levels that every stored entry must carry (the shallow prefix).
_REQUIRED = ("normalized", "prelift")


def _entry_fields(entry: GeomodelEntry) -> dict:
    return {
        name: getattr(entry, name)
        for name in LEVELS
        if getattr(entry, name) is not None
    }


def _entry_from_fields(key: str, fields: dict) -> Optional[GeomodelEntry]:
    if any(name not in fields for name in _REQUIRED):
        return None
    return GeomodelEntry(
        key=key,
        normalized=np.asarray(fields["normalized"]),
        prelift=np.asarray(fields["prelift"]),
        spectra=None if "spectra" not in fields else np.asarray(fields["spectra"]),
        contribution=(
            None if "contribution" not in fields
            else np.asarray(fields["contribution"])
        ),
    )


class CacheStore:
    """Interface + shared counters. ``get``/``put`` take the version
    namespace explicitly so one store serves heterogeneous replicas."""

    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.puts = 0

    def get(self, version: str, key: str) -> Optional[GeomodelEntry]:
        raise NotImplementedError

    def put(self, version: str, key: str, entry: GeomodelEntry) -> None:
        raise NotImplementedError

    @property
    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "puts": self.puts,
            "hit_rate": self.hits / lookups if lookups else 0.0,
        }


class DictCacheStore(CacheStore):
    """Shared-dict backend: replicas in the same process (threaded gateway,
    tests, benchmarks) share one instance. Entries are stored and returned
    as copies — the store can never alias a replica's live arrays."""

    def __init__(self):
        super().__init__()
        self._data: dict = {}
        self._lock = threading.Lock()

    def get(self, version: str, key: str) -> Optional[GeomodelEntry]:
        with self._lock:
            fields = self._data.get((version, key))
            if fields is None:
                self.misses += 1
                return None
            self.hits += 1
            return _entry_from_fields(key, {k: v.copy() for k, v in fields.items()})

    def put(self, version: str, key: str, entry: GeomodelEntry) -> None:
        fields = {k: v.copy() for k, v in _entry_fields(entry).items()}
        with self._lock:
            old = self._data.get((version, key))
            # Never replace a fuller entry with a shallower one: a
            # prelift-level replica must not strip the deep levels a
            # deep-level replica already published.
            if old is not None and set(fields) <= set(old):
                return
            self._data[(version, key)] = fields
            self.puts += 1

    @property
    def stats(self) -> dict:
        with self._lock:
            entries = len(self._data)
            nbytes = sum(
                v.nbytes for fields in self._data.values() for v in fields.values()
            )
        return {**super().stats, "entries": entries, "bytes": nbytes}


class FileCacheStore(CacheStore):
    """File backend: one ``.npz`` per entry at ``root/<version>/<key>.npz``.

    Writes land in a same-directory temp file first, then ``os.replace``
    (atomic on POSIX), so a concurrent reader in another replica process
    sees either the old entry or the new one — never a torn file. A
    corrupt/partial file (e.g. a crashed writer on a non-atomic
    filesystem) is treated as a miss and removed.
    """

    def __init__(self, root: str):
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, version: str, key: str) -> str:
        return os.path.join(self.root, version, f"{key}.npz")

    def get(self, version: str, key: str) -> Optional[GeomodelEntry]:
        path = self._path(version, key)
        try:
            with np.load(path) as npz:
                fields = {name: npz[name] for name in npz.files}
        except FileNotFoundError:
            self.misses += 1
            return None
        except (OSError, ValueError, KeyError):
            try:
                os.remove(path)
            except OSError:
                pass
            self.misses += 1
            return None
        entry = _entry_from_fields(key, fields)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return entry

    def put(self, version: str, key: str, entry: GeomodelEntry) -> None:
        fields = _entry_fields(entry)
        path = self._path(version, key)
        if os.path.exists(path):
            try:
                with np.load(path) as npz:
                    if set(fields) <= set(npz.files):
                        return  # existing entry is at least as deep
            except (OSError, ValueError):
                pass  # corrupt: fall through and rewrite
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **fields)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self.puts += 1

    @property
    def stats(self) -> dict:
        entries = 0
        nbytes = 0
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                if fn.endswith(".npz"):
                    entries += 1
                    try:
                        nbytes += os.path.getsize(os.path.join(dirpath, fn))
                    except OSError:
                        pass
        return {**super().stats, "entries": entries, "bytes": nbytes}


def open_cache_store(spec: str) -> CacheStore:
    """Build a store from a CLI spec: ``"dict"`` / ``"mem"`` for the shared
    in-process dict, anything else is a filesystem root."""
    if spec in ("dict", "mem", "dict://"):
        return DictCacheStore()
    return FileCacheStore(spec)
