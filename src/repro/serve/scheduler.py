"""Family-generic slot scheduler: continuous batching over any ModelRunner.

The serving subsystem is split into two layers. This module is the
model-agnostic half: a fixed pool of ``max_slots`` request slots, continuous
admission (a queued request is installed the moment a slot frees — no
full-batch barrier, "continuous batching" a la Orca/vLLM), per-slot
progress, retirement hooks, and in-flight request DEDUP: when the runner
can key requests by content (``request_key``), an identical request
submitted while its twin is queued/active attaches to that primary as a
follower — it never occupies a slot, and the primary's outputs are fanned
out to it at retirement (``fanout``). What a "step" computes is delegated
to a ``ModelRunner`` — one batched decode for the token engine, one batched
FNO surrogate application for PDE scenarios — so LLM token requests and
PDE-scenario requests share exactly this scheduling logic.

The contract the runner must honor:

  * ``admit(slot, request)`` installs the request's state into ``slot``
    (prefill + cache install for tokens; normalize + stage the input field
    for scenarios). Called once per request, before its first step. If it
    raises, the scheduler marks the request FAILED (``request.error`` set,
    collected in ``Scheduler.failed``) and stays serviceable — the slot is
    offered to the next queued request.
  * ``step(slots, active)`` advances EVERY active slot by one unit of
    progress in a single batched computation, mutates the requests with
    their new outputs, and returns the slot indices that just finished.
  * ``retire(slot, request)`` releases per-slot state after the scheduler
    pulls the request out of the pool (optional cleanup; slots are reused).
  * ``request_key(request)`` (optional) — a hashable content key (or None
    to opt a request out); equal keys mean byte-identical work, enabling
    dedup. Runners providing it must also provide
    ``fanout(primary, follower)`` to copy a retired primary's outputs onto
    a follower.

Requests are opaque to the scheduler except for the attributes it manages:
``done`` (set True on retirement/failure), ``error`` (the admit exception,
on failure), and the latency timestamps (``submitted_s`` / ``admitted_s``
/ ``finished_s``, ``time.perf_counter`` values) that the serving CLIs
report per-request latency from.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import List, Optional, Protocol, Sequence


class ModelRunner(Protocol):
    """What the scheduler needs from a model family (see module docstring)."""

    def admit(self, slot: int, request) -> None: ...

    def step(self, slots: Sequence[Optional[object]], active: Sequence[int]) -> Sequence[int]: ...

    def retire(self, slot: int, request) -> None: ...


class Scheduler:
    """Slot pool + continuous admission + dedup + retirement over a ModelRunner."""

    def __init__(self, runner: ModelRunner, max_slots: int, *, dedup: bool = True):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.runner = runner
        self.max_slots = max_slots
        self.slots: List[Optional[object]] = [None] * max_slots
        self.queue: deque = deque()
        self.finished: list = []
        self.failed: list = []
        self.steps = 0
        # dedup state: primaries in flight by content key; followers by
        # primary identity (requests need not be hashable themselves)
        self._request_key = getattr(runner, "request_key", None) if dedup else None
        self._primary_by_key: dict = {}
        self._followers: dict = {}
        self.dedup_attached = 0

    # -- API ----------------------------------------------------------------
    def submit(self, request) -> None:
        request.submitted_s = time.perf_counter()
        if self._request_key is not None:
            key = self._request_key(request)
            if key is not None:
                primary = self._primary_by_key.get(key)
                if primary is not None:
                    # identical work already queued/active: ride its slot
                    request.admitted_s = time.perf_counter()
                    self._followers.setdefault(id(primary), []).append(request)
                    self.dedup_attached += 1
                    return
                self._primary_by_key[key] = request
                request._dedup_key = key
        self.queue.append(request)

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def pending(self) -> int:
        """Requests not yet finished/failed: queued + active + followers."""
        n_active = len(self.active_slots())
        n_followers = sum(len(f) for f in self._followers.values())
        return len(self.queue) + n_active + n_followers

    def admit_waiting(self) -> List[int]:
        """Fill free slots from the queue (FIFO). Returns admitted slots.

        A request whose ``runner.admit`` raises is marked failed (not
        silently dropped) and the freed slot is offered to the next queued
        request — one bad request cannot wedge the pool.
        """
        admitted = []
        for i, occupant in enumerate(self.slots):
            if occupant is not None:
                continue
            while self.queue:
                request = self.queue.popleft()
                try:
                    self.runner.admit(i, request)
                except Exception as exc:  # noqa: BLE001 — any admit error
                    self._fail(request, exc)
                    continue
                request.admitted_s = time.perf_counter()
                self.slots[i] = request
                admitted.append(i)
                break
        return admitted

    def step(self) -> int:
        """One tick: admit, one batched runner step, retire. Returns the
        number of slots that were active during the step."""
        self.admit_waiting()
        active = self.active_slots()
        if not active:
            return 0
        finished = self.runner.step(self.slots, active)
        self.steps += 1
        for i in finished:
            request = self.slots[i]
            self.runner.retire(i, request)
            request.done = True
            request.finished_s = time.perf_counter()
            self.finished.append(request)
            self.slots[i] = None
            self._resolve_dedup(request)
        return len(active)

    def run_until_done(self, max_steps: int = 1000) -> list:
        """Drive ticks until the pool drains. If ``max_steps`` is exhausted
        with work still queued/active, the partial result is NOT silent: a
        RuntimeWarning reports how many requests are unfinished."""
        while self.has_work() and self.steps < max_steps:
            self.step()
        if self.has_work():
            warnings.warn(
                f"run_until_done: max_steps={max_steps} exhausted with "
                f"{self.pending()} request(s) still queued/active "
                f"({len(self.finished)} finished, {len(self.failed)} failed) "
                f"— raise max_steps",
                RuntimeWarning,
                stacklevel=2,
            )
        return self.finished

    # -- internals ----------------------------------------------------------
    def _fail(self, request, exc: Exception) -> None:
        request.error = exc
        request.done = True
        request.finished_s = time.perf_counter()
        self.failed.append(request)
        # followers were promised this primary's outputs: fail them too
        key = getattr(request, "_dedup_key", None)
        if key is not None and self._primary_by_key.get(key) is request:
            del self._primary_by_key[key]
        for follower in self._followers.pop(id(request), []):
            follower.error = exc
            follower.done = True
            follower.finished_s = time.perf_counter()
            self.failed.append(follower)

    def _resolve_dedup(self, request) -> None:
        key = getattr(request, "_dedup_key", None)
        if key is not None and self._primary_by_key.get(key) is request:
            del self._primary_by_key[key]
        followers = self._followers.pop(id(request), [])
        for follower in followers:
            self.runner.fanout(request, follower)
            follower.done = True
            follower.finished_s = time.perf_counter()
            self.finished.append(follower)
