"""Family-generic slot scheduler: continuous batching over any ModelRunner.

The serving subsystem is split into two layers. This module is the
model-agnostic half: a fixed pool of ``max_slots`` request slots, continuous
admission (a queued request is installed the moment a slot frees — no
full-batch barrier, "continuous batching" a la Orca/vLLM), per-slot
progress, and retirement hooks. What a "step" computes is delegated to a
``ModelRunner`` — one batched decode for the token engine, one batched FNO
surrogate application for PDE scenarios — so LLM token requests and
PDE-scenario requests share exactly this scheduling logic.

The contract the runner must honor:

  * ``admit(slot, request)`` installs the request's state into ``slot``
    (prefill + cache install for tokens; normalize + stage the input field
    for scenarios). Called once per request, before its first step.
  * ``step(slots, active)`` advances EVERY active slot by one unit of
    progress in a single batched computation, mutates the requests with
    their new outputs, and returns the slot indices that just finished.
  * ``retire(slot, request)`` releases per-slot state after the scheduler
    pulls the request out of the pool (optional cleanup; slots are reused).

Requests are opaque to the scheduler except for two attributes it manages:
``done`` (set True on retirement) and the latency timestamps
(``submitted_s`` / ``admitted_s`` / ``finished_s``, ``time.perf_counter``
values) that the serving CLIs report per-request latency from.
"""
from __future__ import annotations

import time
from collections import deque
from typing import List, Optional, Protocol, Sequence


class ModelRunner(Protocol):
    """What the scheduler needs from a model family (see module docstring)."""

    def admit(self, slot: int, request) -> None: ...

    def step(self, slots: Sequence[Optional[object]], active: Sequence[int]) -> Sequence[int]: ...

    def retire(self, slot: int, request) -> None: ...


class Scheduler:
    """Slot pool + continuous admission + retirement over a ModelRunner."""

    def __init__(self, runner: ModelRunner, max_slots: int):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.runner = runner
        self.max_slots = max_slots
        self.slots: List[Optional[object]] = [None] * max_slots
        self.queue: deque = deque()
        self.finished: list = []
        self.steps = 0

    # -- API ----------------------------------------------------------------
    def submit(self, request) -> None:
        request.submitted_s = time.perf_counter()
        self.queue.append(request)

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def admit_waiting(self) -> List[int]:
        """Fill free slots from the queue (FIFO). Returns admitted slots."""
        admitted = []
        for i, occupant in enumerate(self.slots):
            if occupant is not None or not self.queue:
                continue
            request = self.queue.popleft()
            self.runner.admit(i, request)
            request.admitted_s = time.perf_counter()
            self.slots[i] = request
            admitted.append(i)
        return admitted

    def step(self) -> int:
        """One tick: admit, one batched runner step, retire. Returns the
        number of slots that were active during the step."""
        self.admit_waiting()
        active = self.active_slots()
        if not active:
            return 0
        finished = self.runner.step(self.slots, active)
        self.steps += 1
        for i in finished:
            request = self.slots[i]
            self.runner.retire(i, request)
            request.done = True
            request.finished_s = time.perf_counter()
            self.finished.append(request)
            self.slots[i] = None
        return len(active)

    def run_until_done(self, max_steps: int = 1000) -> list:
        while self.has_work() and self.steps < max_steps:
            self.step()
        return self.finished
