"""Family-generic slot scheduler: continuous batching over any ModelRunner.

The serving subsystem is split into two layers. This module is the
model-agnostic half: a fixed pool of ``max_slots`` request slots, continuous
admission (a queued request is installed the moment a slot frees — no
full-batch barrier, "continuous batching" a la Orca/vLLM), per-slot
progress, retirement hooks, and in-flight request DEDUP: when the runner
can key requests by content (``request_key``), an identical request
submitted while its twin is queued/active attaches to that primary as a
follower — it never occupies a slot, and the primary's outputs are fanned
out to it at retirement (``fanout``). What a "step" computes is delegated
to a ``ModelRunner`` — one batched decode for the token engine, one batched
FNO surrogate application for PDE scenarios — so LLM token requests and
PDE-scenario requests share exactly this scheduling logic.

The contract the runner must honor:

  * ``admit(slot, request)`` installs the request's state into ``slot``
    (prefill + cache install for tokens; normalize + stage the input field
    for scenarios). Called once per request, before its first step. If it
    raises, the scheduler marks the request FAILED (``request.error`` set,
    collected in ``Scheduler.failed``) and stays serviceable — the slot is
    offered to the next queued request.
  * ``step(slots, active)`` advances EVERY active slot by one unit of
    progress in a single batched computation, mutates the requests with
    their new outputs, and returns the slot indices that just finished.
  * ``retire(slot, request)`` releases per-slot state after the scheduler
    pulls the request out of the pool (optional cleanup; slots are reused).
  * ``request_key(request)`` (optional) — a hashable content key (or None
    to opt a request out); equal keys mean byte-identical work, enabling
    dedup. Runners providing it must also provide
    ``fanout(primary, follower)`` to copy a retired primary's outputs onto
    a follower.

Requests are opaque to the scheduler except for the attributes it manages:
``done`` (set True on retirement/failure), ``error`` (the admit exception,
on failure), and the latency timestamps (``submitted_s`` / ``admitted_s``
/ ``finished_s``, ``time.perf_counter`` values) that the serving CLIs
report per-request latency from. Two OPTIONAL request attributes feed the
admission policy: ``priority`` (int, higher admitted first when slots
contend) and ``deadline_s`` (relative seconds from submission; within a
priority class the earliest absolute deadline is admitted first — EDF).
Requests carrying neither behave exactly as before: pure FIFO.
"""
from __future__ import annotations

import math
import time
import warnings
from collections import deque
from typing import List, Optional, Protocol, Sequence


class ModelRunner(Protocol):
    """What the scheduler needs from a model family (see module docstring)."""

    def admit(self, slot: int, request) -> None: ...

    def step(self, slots: Sequence[Optional[object]], active: Sequence[int]) -> Sequence[int]: ...

    def retire(self, slot: int, request) -> None: ...


class Scheduler:
    """Slot pool + continuous admission + dedup + retirement over a ModelRunner."""

    def __init__(self, runner: ModelRunner, max_slots: int, *, dedup: bool = True):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.runner = runner
        self.max_slots = max_slots
        self.slots: List[Optional[object]] = [None] * max_slots
        self.queue: deque = deque()
        self.finished: list = []
        self.failed: list = []
        self.steps = 0
        # dedup state: primaries in flight by content key; followers by
        # primary identity (requests need not be hashable themselves)
        self._request_key = getattr(runner, "request_key", None) if dedup else None
        self._primary_by_key: dict = {}
        self._followers: dict = {}
        self.dedup_attached = 0
        self._seq = 0  # FIFO tie-break for the priority/deadline order

    # -- API ----------------------------------------------------------------
    def submit(self, request) -> None:
        request.submitted_s = time.perf_counter()
        request._seq = self._seq
        self._seq += 1
        deadline = getattr(request, "deadline_s", None)
        request._deadline_abs = (
            request.submitted_s + deadline if deadline is not None else None
        )
        if self._request_key is not None:
            key = self._request_key(request)
            if key is not None:
                primary = self._primary_by_key.get(key)
                if primary is not None:
                    # identical work already queued/active: ride its slot.
                    # A follower is admitted when its PRIMARY is: attaching
                    # to a still-queued primary leaves admitted_s unset
                    # (stamped in admit_waiting alongside the primary), so
                    # follower latency stats see the real queue wait.
                    if getattr(primary, "admitted_s", None) is not None:
                        request.admitted_s = time.perf_counter()
                    self._followers.setdefault(id(primary), []).append(request)
                    self.dedup_attached += 1
                    return
                self._primary_by_key[key] = request
                request._dedup_key = key
        self.queue.append(request)

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def pending(self) -> int:
        """Requests not yet finished/failed: queued + active + followers."""
        n_active = len(self.active_slots())
        n_followers = sum(len(f) for f in self._followers.values())
        return len(self.queue) + n_active + n_followers

    def admit_waiting(self) -> List[int]:
        """Fill free slots from the queue (priority > deadline > FIFO).
        Returns admitted slots.

        A request whose ``runner.admit`` raises is marked failed (not
        silently dropped) and the freed slot is offered to the next queued
        request — one bad request cannot wedge the pool.
        """
        admitted = []
        for i, occupant in enumerate(self.slots):
            if occupant is not None:
                continue
            while self.queue:
                request = self._pop_next()
                try:
                    self.runner.admit(i, request)
                except Exception as exc:  # noqa: BLE001 — any admit error
                    self._fail(request, exc)
                    continue
                request.admitted_s = time.perf_counter()
                # followers that attached while this primary was queued
                # become admitted with it (they ride this very slot)
                for follower in self._followers.get(id(request), []):
                    if getattr(follower, "admitted_s", None) is None:
                        follower.admitted_s = request.admitted_s
                self.slots[i] = request
                admitted.append(i)
                break
        return admitted

    def step(self) -> int:
        """One tick: admit, one batched runner step, retire. Returns the
        number of slots that were active during the step."""
        self.admit_waiting()
        active = self.active_slots()
        if not active:
            return 0
        finished = self.runner.step(self.slots, active)
        self.steps += 1
        for i in finished:
            request = self.slots[i]
            self.runner.retire(i, request)
            request.done = True
            request.finished_s = time.perf_counter()
            self.finished.append(request)
            self.slots[i] = None
            self._resolve_dedup(request)
        return len(active)

    def run_until_done(self, max_steps: int = 1000) -> list:
        """Drive ticks until the pool drains. ``max_steps`` budgets THIS
        call, not the scheduler's lifetime — a reused scheduler (a gateway
        drains it once per arrival wave) gets a fresh budget every call,
        instead of spuriously bailing once cumulative ``self.steps``
        crosses the threshold. If the budget is exhausted with work still
        queued/active, the partial result is NOT silent: a RuntimeWarning
        reports how many requests are unfinished."""
        start_steps = self.steps
        while self.has_work() and self.steps - start_steps < max_steps:
            self.step()
        if self.has_work():
            warnings.warn(
                f"run_until_done: max_steps={max_steps} exhausted with "
                f"{self.pending()} request(s) still queued/active "
                f"({len(self.finished)} finished, {len(self.failed)} failed) "
                f"— raise max_steps",
                RuntimeWarning,
                stacklevel=2,
            )
        return self.finished

    def drain_unfinished(self) -> list:
        """Remove and return every not-yet-finished request: queued, active
        in a slot, and dedup followers. The failover path — a gateway pulls
        unfinished work off a replica whose runner broke and resubmits it
        elsewhere. The runner is deliberately NOT consulted (it may be the
        broken thing); slots are cleared and dedup state reset so the
        requests can be submitted to a different scheduler."""
        orphans = list(self.queue)
        self.queue.clear()
        for i, request in enumerate(self.slots):
            if request is not None:
                orphans.append(request)
                self.slots[i] = None
        for followers in self._followers.values():
            orphans.extend(followers)
        self._followers.clear()
        self._primary_by_key.clear()
        for request in orphans:
            if hasattr(request, "_dedup_key"):
                del request._dedup_key
        return orphans

    # -- internals ----------------------------------------------------------
    def _pop_next(self):
        """Pop the queued request to admit next: highest ``priority``, then
        earliest absolute deadline (EDF), then submission order. Requests
        without either attribute all share the default key, so the scan
        degenerates to exact FIFO."""
        best_i, best_key = 0, self._admit_order(self.queue[0])
        for i in range(1, len(self.queue)):
            key = self._admit_order(self.queue[i])
            if key < best_key:
                best_i, best_key = i, key
        if best_i == 0:
            return self.queue.popleft()
        request = self.queue[best_i]
        del self.queue[best_i]
        return request

    @staticmethod
    def _admit_order(request) -> tuple:
        deadline = getattr(request, "_deadline_abs", None)
        return (
            -(getattr(request, "priority", 0) or 0),
            deadline if deadline is not None else math.inf,
            getattr(request, "_seq", 0),
        )

    def _fail(self, request, exc: Exception) -> None:
        request.error = exc
        request.done = True
        request.finished_s = time.perf_counter()
        self.failed.append(request)
        # followers were promised this primary's outputs: fail them too
        key = getattr(request, "_dedup_key", None)
        if key is not None and self._primary_by_key.get(key) is request:
            del self._primary_by_key[key]
        for follower in self._followers.pop(id(request), []):
            follower.error = exc
            follower.done = True
            follower.finished_s = time.perf_counter()
            self.failed.append(follower)

    def _resolve_dedup(self, request) -> None:
        key = getattr(request, "_dedup_key", None)
        if key is not None and self._primary_by_key.get(key) is request:
            del self._primary_by_key[key]
        followers = self._followers.pop(id(request), [])
        for follower in followers:
            self.runner.fanout(request, follower)
            follower.done = True
            follower.finished_s = time.perf_counter()
            self.finished.append(follower)
