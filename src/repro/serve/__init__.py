"""Family-generic serving: one slot scheduler, per-family model runners.

``Scheduler`` owns slots/admission/retirement; ``TransformerRunner`` (token
decode) and ``FNORunner`` (PDE-scenario surrogate inference) plug into it.
``Engine`` is the LLM-facing thin client kept for API compatibility.
``Gateway`` is the fleet layer: N independent replica schedulers behind
one backlog/health-aware, cache-affine front door with an autoscaling
hook; ``serve_open_loop`` drives an open-loop arrival process through it.
"""
from repro.serve.cache_store import (  # noqa: F401
    CacheStore, DictCacheStore, FileCacheStore, open_cache_store,
)
from repro.serve.engine import (  # noqa: F401
    Engine, Request, SERVABLE_FAMILIES, TransformerRunner,
)
from repro.serve.fno_runner import (  # noqa: F401
    FNORunner, ScenarioRequest, default_feedback,
)
from repro.serve.gateway import (  # noqa: F401
    Gateway, OpenLoopReport, POLICIES, ReplicaHandle, serve_open_loop,
)
from repro.serve.geomodel_cache import (  # noqa: F401
    GeomodelCache, GeomodelEntry, content_key,
)
from repro.serve.scheduler import ModelRunner, Scheduler  # noqa: F401
