"""Content-hash geomodel cache — the KV-cache of PDE serving.

The paper's payoff workloads (well-placement optimization, UQ) run
thousands of scenarios against the *same* permeability geomodel: only the
well locations (and, across rollout steps, the saturation state) change.
Without a cache every request re-normalizes the geomodel channels and
re-lifts them through the encoder, per request AND per rollout step — the
PDE analogue of an LLM server re-prefilling a shared prompt prefix for
every completion.

This module caches the geomodel-dependent intermediates keyed by a content
hash of the RAW static channels, as a MULTI-LEVEL entry (shallow -> deep):

  * ``normalized``    — the static channels after the store's persisted
    per-channel normalization (what ingress would recompute per request);
  * ``prelift``       — their pre-activation encoder lift
    (``core.fno.encoder_prelift``), the reusable prefix of the split
    forward;
  * ``spectra``       — the truncated kept-mode spectrum of the static
    first hidden state S(GELU(prelift + b)) (``core.fno.spectral_prelift``);
  * ``contribution``  — its first-block weight mix W_0 . S(h_static), the
    term summed straight into the dynamic remainder's pre-activation by
    ``fno_forward_deep_split``.

Each level is derived from the previous one, so the LRU may drop the DEEP
levels of a cold entry (freeing the complex64 tensors) while keeping the
shallow ones — a deep re-miss then recomputes only the spectral prefix,
not the normalization. Eviction (full or deep-only) never mutates an entry
a caller already holds: deep-stripping replaces the stored entry with a
copy, so slots serving an in-flight rollout keep their levels. Counters
(hits/misses/evictions/deep_evictions/bytes, per-level bytes) feed the
serving CLIs' hit-rate reports; lookups happen once per slot per scheduler
tick, so the hit-rate reflects reuse across requests AND rollout steps.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np

#: Cache levels, shallow to deep. The deep suffix is what deep-eviction drops.
LEVELS = ("normalized", "prelift", "spectra", "contribution")
DEEP_LEVELS = ("spectra", "contribution")

_HASH_CHUNK_ROWS_BYTES = 4 << 20


def content_key(arr: np.ndarray) -> str:
    """Content hash of an array's dtype + shape + raw bytes.

    dtype and shape are part of the digest, so a reshaped or reinterpreted
    buffer can never collide with the original. Contiguous arrays are fed
    to blake2b directly via the buffer protocol (zero copy); non-contiguous
    ones are hashed in bounded leading-axis slabs instead of one full
    ``tobytes()`` copy — the digest equals the contiguous-copy digest
    because C-order bytes concatenate along the leading axis.
    """
    a = np.asarray(arr)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    if a.size:
        if a.flags["C_CONTIGUOUS"]:
            h.update(a)
        elif a.ndim == 0:
            h.update(a.tobytes())
        else:
            row_bytes = max(1, a.nbytes // max(1, a.shape[0]))
            rows = max(1, _HASH_CHUNK_ROWS_BYTES // row_bytes)
            for s in range(0, a.shape[0], rows):
                h.update(np.ascontiguousarray(a[s:s + rows]))
    return h.hexdigest()


@dataclasses.dataclass
class GeomodelEntry:
    """Cached intermediates for one geomodel (one static-channel content).

    The deep levels are optional: prelift-level serving never computes
    them, and deep-eviction strips them from the cache's copy.
    """

    key: str
    normalized: np.ndarray  # [c_static, *grid] encoded static channels
    prelift: np.ndarray     # [width, *grid] their encoder pre-activation lift
    spectra: Optional[np.ndarray] = None       # [width, 2mx, 2my, 2mz, mt] c64
    contribution: Optional[np.ndarray] = None  # [width, 2mx, 2my, 2mz, mt] c64

    @property
    def nbytes(self) -> int:
        return sum(self.level_bytes.values())

    @property
    def level_bytes(self) -> dict:
        return {
            name: (0 if getattr(self, name) is None else getattr(self, name).nbytes)
            for name in LEVELS
        }

    @property
    def has_deep(self) -> bool:
        return self.spectra is not None or self.contribution is not None

    def without_deep(self) -> "GeomodelEntry":
        """A copy with the deep levels dropped (the original is untouched,
        so in-flight holders keep theirs)."""
        return dataclasses.replace(self, spectra=None, contribution=None)


class GeomodelCache:
    """LRU cache of ``GeomodelEntry`` under a byte budget.

    Eviction is two-stage: the LRU entry first loses its deep levels
    (``deep_evictions``), and is only fully evicted (``evictions``) once
    already shallow. Byte accounting uses sizes recorded at put-time, so
    callers that grow an entry's levels in place must re-``put`` it.
    """

    def __init__(self, max_bytes: int = 256 << 20):
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.max_bytes = max_bytes
        self._entries: "OrderedDict[str, GeomodelEntry]" = OrderedDict()
        self._sizes: dict = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.deep_evictions = 0
        self.bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[GeomodelEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)  # MRU
        self.hits += 1
        return entry

    def put(self, key: str, entry: GeomodelEntry) -> GeomodelEntry:
        """Insert (or refresh) an entry, then evict LRU-first until the
        byte budget holds. An entry larger than the whole budget is evicted
        immediately — the budget is strict; callers keep their reference."""
        if self._entries.pop(key, None) is not None:
            self.bytes -= self._sizes.pop(key)
        self._entries[key] = entry
        self._sizes[key] = entry.nbytes
        self.bytes += entry.nbytes
        self._evict()
        return entry

    def _evict(self) -> None:
        while self.bytes > self.max_bytes and self._entries:
            key = next(iter(self._entries))
            lru = self._entries[key]
            if lru.has_deep:
                stripped = lru.without_deep()
                delta = self._sizes[key] - stripped.nbytes
                if delta > 0:
                    # Replace in place (same LRU position) with a deep-less
                    # copy; the old object — possibly held by a serving
                    # slot — keeps its levels.
                    self._entries[key] = stripped
                    self._sizes[key] = stripped.nbytes
                    self.bytes -= delta
                    self.deep_evictions += 1
                    continue
            del self._entries[key]
            self.bytes -= self._sizes.pop(key)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()
        self._sizes.clear()
        self.bytes = 0

    @property
    def stats(self) -> dict:
        lookups = self.hits + self.misses
        level_bytes = dict.fromkeys(LEVELS, 0)
        for entry in self._entries.values():
            for name, n in entry.level_bytes.items():
                level_bytes[name] += n
        return {
            "entries": len(self._entries),
            "bytes": self.bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "deep_evictions": self.deep_evictions,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "level_bytes": level_bytes,
        }
