"""Content-hash geomodel cache — the KV-cache of PDE serving.

The paper's payoff workloads (well-placement optimization, UQ) run
thousands of scenarios against the *same* permeability geomodel: only the
well locations (and, across rollout steps, the saturation state) change.
Without a cache every request re-normalizes the geomodel channels and
re-lifts them through the encoder, per request AND per rollout step — the
PDE analogue of an LLM server re-prefilling a shared prompt prefix for
every completion.

This module caches the geomodel-dependent intermediates keyed by a content
hash of the RAW static channels:

  * ``normalized`` — the static channels after the store's persisted
    per-channel normalization (what ingress would recompute per request);
  * ``prelift``    — their pre-activation encoder lift
    (``core.fno.encoder_prelift``), the reusable prefix of the split
    forward: the per-request forward only lifts the dynamic channels and
    adds this cached partial sum.

Entries are LRU-evicted against a byte budget. Eviction only drops the
cache's reference — slots serving an in-flight request hold their own
reference to the entry's arrays, so eviction never invalidates active
work (no pinning needed). Counters (hits/misses/evictions/bytes) feed the
serving CLIs' hit-rate reports; lookups happen once per slot per scheduler
tick, so the hit-rate reflects reuse across requests AND rollout steps.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np


def content_key(arr: np.ndarray) -> str:
    """Content hash of an array's dtype + shape + raw bytes."""
    a = np.ascontiguousarray(arr)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a)
    return h.hexdigest()


@dataclasses.dataclass
class GeomodelEntry:
    """Cached intermediates for one geomodel (one static-channel content)."""

    key: str
    normalized: np.ndarray  # [c_static, *grid] encoded static channels
    prelift: np.ndarray     # [width, *grid] their encoder pre-activation lift

    @property
    def nbytes(self) -> int:
        return self.normalized.nbytes + self.prelift.nbytes


class GeomodelCache:
    """LRU cache of ``GeomodelEntry`` under a byte budget."""

    def __init__(self, max_bytes: int = 256 << 20):
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.max_bytes = max_bytes
        self._entries: "OrderedDict[str, GeomodelEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[GeomodelEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)  # MRU
        self.hits += 1
        return entry

    def put(self, key: str, entry: GeomodelEntry) -> GeomodelEntry:
        """Insert (or refresh) an entry, then evict LRU-first until the
        byte budget holds. An entry larger than the whole budget is evicted
        immediately — the budget is strict; callers keep their reference."""
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes -= old.nbytes
        self._entries[key] = entry
        self.bytes += entry.nbytes
        while self.bytes > self.max_bytes and self._entries:
            _, evicted = self._entries.popitem(last=False)
            self.bytes -= evicted.nbytes
            self.evictions += 1
        return entry

    def clear(self) -> None:
        self._entries.clear()
        self.bytes = 0

    @property
    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "bytes": self.bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hits / lookups if lookups else 0.0,
        }
