"""Continuous-batching-lite serving engine.

Slot-based scheduler over the family-generic decode step: a fixed pool of
``max_batch`` slots, each holding one request's cache; new requests are
admitted into free slots as soon as they open (no full-batch barrier —
"continuous batching" a la Orca/vLLM, minus paging since our caches are
dense per-slot). Per-slot sequence positions differ, so the decode step is
vmapped over the slot dim with a per-slot index vector.

Greedy sampling; EOS or max_tokens retires a slot.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf_lib
from repro.models import whisper as wh_lib
from repro.models.policy import LOCAL, ParallelPolicy


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_tokens: int = 16
    eos_id: Optional[int] = None
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    length: int = 0


class Engine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_len: int = 128,
        max_batch: int = 4,
        policy: ParallelPolicy = LOCAL,
    ):
        if cfg.family == "encdec":
            raise NotImplementedError("use whisper_* serving entry points")
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self.slots: List[_Slot] = [_Slot() for _ in range(max_batch)]
        # Cache with batch dim = slots (axis differs per subtree: stacked
        # layer leaves carry it at axis 1).
        self.cache = tf_lib.init_cache(cfg, max_batch, max_len, policy=policy)
        self._axes = tf_lib.cache_batch_axes(self.cache)

        axes = self._axes

        def decode_one(params, token, cache_stripped, index):
            # vmap strips the slot axis; restore a batch dim of 1 per leaf
            cache1 = jax.tree.map(
                lambda a, ax: jnp.expand_dims(a, ax), cache_stripped, axes
            )
            logits, new_cache = tf_lib.lm_decode_step(params, token, cache1, index, cfg, policy)
            stripped = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax), new_cache, axes)
            return logits[0], stripped  # logits: [vocab]

        # vmap over slots: params broadcast, token/cache/index per-slot
        self._step = jax.jit(
            jax.vmap(decode_one, in_axes=(None, 0, self._axes, 0), out_axes=(0, self._axes))
        )
        self._prefill = jax.jit(
            lambda p, t: tf_lib.lm_prefill(p, t, cfg, policy, max_len=max_len)
        )
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.steps = 0

    # -- API ----------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.request is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache = self._prefill(self.params, tokens)
            nxt = int(jnp.argmax(logits[0]))
            req.output.append(nxt)
            # install the request's cache into slot i along each leaf's
            # batch axis (the prefill cache has batch 1 there)
            def install(full, new, ax):
                idx = [slice(None)] * full.ndim
                idx[ax] = i
                return full.at[tuple(idx)].set(jnp.take(new, 0, axis=ax).astype(full.dtype))

            self.cache = jax.tree.map(install, self.cache, cache, self._axes)
            slot.request = req
            slot.length = len(req.prompt) + 1

    def step(self) -> int:
        """One engine tick: admit, batched decode, retire. Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.request is not None]
        if not active:
            return 0
        tokens = jnp.asarray(
            [[s.request.output[-1] if s.request else 0] for s in self.slots],
            jnp.int32,
        )  # [slot, 1]
        index = jnp.asarray(
            [s.length - 1 if s.request else 0 for s in self.slots], jnp.int32
        )
        logits, new_cache = self._step(self.params, tokens[:, None, :], self.cache, index)
        self.cache = new_cache
        self.steps += 1
        nxt = jnp.argmax(logits, axis=-1)  # [slot]
        for i in active:
            slot = self.slots[i]
            req = slot.request
            tok = int(nxt[i])
            req.output.append(tok)
            slot.length += 1
            if (
                (req.eos_id is not None and tok == req.eos_id)
                or len(req.output) >= req.max_tokens
                or slot.length >= self.max_len
            ):
                req.done = True
                self.finished.append(req)
                self.slots[i] = _Slot()
        return len(active)

    def run_until_done(self, max_steps: int = 1000):
        while (self.queue or any(s.request for s in self.slots)) and self.steps < max_steps:
            self.step()
        return self.finished
