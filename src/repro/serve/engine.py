"""Token-family ModelRunner + the LLM serving engine.

The slot-pool/admission/retirement logic lives in ``serve.scheduler``;
this module contributes the token-decoding half of the split: a
``TransformerRunner`` that prefillls a request's cache on admission and
advances every active slot by one greedy decode step per scheduler tick.
Per-slot sequence positions differ, so the decode step is vmapped over the
slot dim with a per-slot index vector. Greedy sampling; EOS or max_tokens
retires a slot.

``Engine`` is a thin client of the shared scheduler kept for API
compatibility (submit / step / run_until_done).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import transformer as tf_lib
from repro.models.policy import LOCAL, ParallelPolicy
from repro.serve.scheduler import Scheduler

# Families Engine can decode with lm_prefill/lm_decode_step. "encdec"
# (whisper) has a separate encoder pass and its own entry points.
SERVABLE_FAMILIES = ("dense", "moe", "ssm", "hybrid")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_tokens: int = 16
    eos_id: Optional[int] = None
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class TransformerRunner:
    """ModelRunner for decoder-family LMs: batched greedy decode over slots."""

    def __init__(
        self,
        cfg,
        params,
        *,
        max_len: int = 128,
        max_slots: int = 4,
        policy: ParallelPolicy = LOCAL,
    ):
        if cfg.family not in SERVABLE_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} is not servable by the token engine "
                f"(supported: {', '.join(SERVABLE_FAMILIES)}); encoder-"
                f"decoder models go through the whisper_* entry points"
            )
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self._lengths = [0] * max_slots
        # Cache with batch dim = slots (axis differs per subtree: stacked
        # layer leaves carry it at axis 1).
        self.cache = tf_lib.init_cache(cfg, max_slots, max_len, policy=policy)
        self._axes = tf_lib.cache_batch_axes(self.cache)

        axes = self._axes

        def decode_one(params, token, cache_stripped, index):
            # vmap strips the slot axis; restore a batch dim of 1 per leaf
            cache1 = jax.tree.map(
                lambda a, ax: jnp.expand_dims(a, ax), cache_stripped, axes
            )
            logits, new_cache = tf_lib.lm_decode_step(params, token, cache1, index, cfg, policy)
            stripped = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax), new_cache, axes)
            return logits[0], stripped  # logits: [vocab]

        # vmap over slots: params broadcast, token/cache/index per-slot
        self._step = jax.jit(
            jax.vmap(decode_one, in_axes=(None, 0, self._axes, 0), out_axes=(0, self._axes))
        )
        self._prefill = jax.jit(
            lambda p, t: tf_lib.lm_prefill(p, t, cfg, policy, max_len=max_len)
        )

    # -- ModelRunner protocol ------------------------------------------------
    def admit(self, slot: int, req: Request) -> None:
        """Prefill the prompt and install the cache into ``slot``."""
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache = self._prefill(self.params, tokens)
        nxt = int(jnp.argmax(logits[0]))
        req.output.append(nxt)

        # install the request's cache into the slot along each leaf's
        # batch axis (the prefill cache has batch 1 there)
        def install(full, new, ax):
            idx = [slice(None)] * full.ndim
            idx[ax] = slot
            return full.at[tuple(idx)].set(jnp.take(new, 0, axis=ax).astype(full.dtype))

        self.cache = jax.tree.map(install, self.cache, cache, self._axes)
        self._lengths[slot] = len(req.prompt) + 1

    def step(self, slots: Sequence[Optional[Request]], active: Sequence[int]) -> list:
        tokens = jnp.asarray(
            [[r.output[-1] if r else 0] for r in slots], jnp.int32
        )  # [slot, 1]
        index = jnp.asarray(
            [self._lengths[i] - 1 if slots[i] else 0 for i in range(len(slots))],
            jnp.int32,
        )
        logits, self.cache = self._step(self.params, tokens[:, None, :], self.cache, index)
        nxt = jnp.argmax(logits, axis=-1)  # [slot]
        finished = []
        for i in active:
            req = slots[i]
            tok = int(nxt[i])
            req.output.append(tok)
            self._lengths[i] += 1
            if (
                (req.eos_id is not None and tok == req.eos_id)
                or len(req.output) >= req.max_tokens
                or self._lengths[i] >= self.max_len
            ):
                finished.append(i)
        return finished

    def retire(self, slot: int, req: Request) -> None:
        self._lengths[slot] = 0  # cache rows are overwritten on next admit


class Engine:
    """LLM serving engine: TransformerRunner behind the shared scheduler."""

    def __init__(
        self,
        cfg,
        params,
        *,
        max_len: int = 128,
        max_batch: int = 4,
        policy: ParallelPolicy = LOCAL,
    ):
        self.cfg = cfg
        self.runner = TransformerRunner(
            cfg, params, max_len=max_len, max_slots=max_batch, policy=policy
        )
        self.scheduler = Scheduler(self.runner, max_batch)

    # -- API (delegates to the scheduler) ------------------------------------
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def step(self) -> int:
        return self.scheduler.step()

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        return self.scheduler.run_until_done(max_steps)

    @property
    def steps(self) -> int:
        return self.scheduler.steps

    @property
    def finished(self) -> List[Request]:
        return self.scheduler.finished

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def slots(self):
        return self.scheduler.slots
