"""PDE-scenario ModelRunner: model-parallel FNO surrogate inference.

The paper's headline result is inference — the trained surrogate simulates
3-D CO2 flow ~5 orders of magnitude faster than the numerical simulator,
which is what makes 1000s-of-scenarios workloads (well-placement
optimization, uncertainty quantification) tractable. This runner serves
that surrogate through the same slot scheduler that serves LLM tokens:

  * one scheduler tick = one batched FNO application over every active
    slot, jit-compiled once per PADDED BUCKET size (active slots are padded
    up to the next bucket so continuous admission doesn't retrigger
    compilation — and, because XLA results are a function of the batch
    SHAPE, a request's output is bit-identical however admission order or
    slot reuse interleaves it with other traffic of the same bucket);
  * the forward is the family's distributed one when the mesh carries model
    axes (paper Alg. 2 / 2-D pencils) — params and batch go through the
    same ``forward_and_specs`` layout contract the training driver uses,
    so a checkpoint trained model-parallel serves model-parallel;
  * ingress applies the store's persisted per-channel normalization (the
    exact stats training normalized with, snapshotted into the
    checkpoint's ``fno_config.json``); egress inverts the target
    normalization, so callers always see physical units;
  * a request may ask for a multi-step autoregressive rollout: the
    de-normalized prediction is fed back through ``feedback`` to build the
    next input (default: repeat the final predicted saturation frame along
    t), re-encoded, and the slot stays busy for the next tick — long-
    horizon forecasts beyond the training window;
  * with ``n_static > 0`` the first ``n_static`` input channels are STATIC
    (the geomodel: permeability/porosity realizations). UQ ensembles reuse
    the same geomodel across thousands of scenarios, so its normalized form
    and encoder prelift are cached by content hash in a shared
    ``GeomodelCache`` (the KV-cache of PDE serving) and the per-tick
    forward only lifts the dynamic channels (``fno_forward_split``);
    ``feedback`` then produces only the DYNAMIC channels — the geomodel
    persists across rollout steps without re-normalize/re-lift. The runner
    also keys requests by content (``request_key``) so the scheduler can
    dedup identical in-flight scenarios.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.fno import (
    FNOConfig, deep_split_forward_and_specs, forward_and_specs, init_params,
    params_with_planes, split_forward_and_specs,
)
from repro.data.loader import Normalizer
from repro.launch.mesh import build_fno_mesh
from repro.serve.cache_store import CacheStore
from repro.serve.geomodel_cache import GeomodelCache, GeomodelEntry, content_key
from repro.train import checkpoint as ckpt_lib

FNO_CONFIG_FILE = "fno_config.json"


@dataclasses.dataclass
class ScenarioRequest:
    """One PDE scenario: an input field -> ``steps`` surrogate applications.

    ``x`` is the RAW (physical-units) input ``[c_in, nx, ny, nz, nt]`` —
    e.g. the binary injector map repeated along t. ``outputs`` collects one
    de-normalized prediction ``[c_out, nx, ny, nz, nt]`` per rollout step.

    ``priority`` / ``deadline_s`` feed the scheduler's admission policy
    (higher priority first; within a priority, earliest deadline — relative
    seconds from submission — first; default: FIFO).
    """

    rid: int
    x: np.ndarray
    steps: int = 1
    outputs: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[Exception] = None
    priority: int = 0
    deadline_s: Optional[float] = None

    @property
    def prediction(self) -> np.ndarray:
        """Final rollout step's de-normalized prediction."""
        if not self.outputs:
            if self.error is not None:
                raise RuntimeError(
                    f"request {self.rid} failed before any rollout step "
                    f"completed: {self.error}"
                ) from self.error
            raise RuntimeError(
                f"request {self.rid} has no completed rollout steps yet — "
                f"it was not served (still queued, or run_until_done ran "
                f"out of max_steps; check Scheduler.finished/.failed)"
            )
        return self.outputs[-1]


def default_feedback(
    y: np.ndarray, cfg: FNOConfig, n_channels: Optional[int] = None
) -> np.ndarray:
    """Next rollout input from a raw prediction: hold the final predicted
    frame and repeat it along t (the saturation state the next window
    evolves from), tiling/truncating channels to ``n_channels`` (default:
    ``in_channels``; runners with static geomodel channels pass the DYNAMIC
    channel count, since the geomodel persists across rollout steps)."""
    want = cfg.in_channels if n_channels is None else n_channels
    nt = cfg.grid[3]
    nxt = np.repeat(y[..., -1:], nt, axis=-1)
    if nxt.shape[0] != want:
        reps = -(-want // nxt.shape[0])
        nxt = np.concatenate([nxt] * reps, axis=0)[:want]
    return np.ascontiguousarray(nxt, np.float32)


def _slice_normalizer(norm: Normalizer, sl: slice) -> Normalizer:
    """Per-channel stats restricted to a channel slice (identity passes
    through: its scalar mean/scale broadcast over any channel count)."""
    if norm.identity or norm.mean.ndim == 0:
        return norm
    return Normalizer(norm.mean[:, sl], norm.scale[:, sl])


def _bucket_ladder(max_slots: int, n_dp: int) -> tuple:
    """Padded-bucket sizes: multiples of the data-parallel size (the batch
    sharding constraint), doubling up to max_slots — so at most
    log2(max_slots/n_dp)+1 jit compilations ever happen."""
    buckets, b = [], n_dp
    while b < max_slots:
        buckets.append(b)
        b *= 2
    buckets.append(max(n_dp, -(-max_slots // n_dp) * n_dp))
    return tuple(sorted(set(buckets)))


class FNORunner:
    """ModelRunner serving batched (data x model)-parallel FNO inference."""

    def __init__(
        self,
        cfg: FNOConfig,
        params,
        *,
        mesh=None,
        model_axis=None,
        max_slots: int = 4,
        x_normalizer: Optional[Normalizer] = None,
        y_normalizer: Optional[Normalizer] = None,
        feedback: Optional[Callable] = None,
        buckets: Optional[Sequence[int]] = None,
        n_static: int = 0,
        cache="auto",
        cache_bytes: int = 256 << 20,
        cache_level: str = "deep",
        cache_store: Optional[CacheStore] = None,
    ):
        if mesh is None:
            mesh, model_axis, _ = build_fno_mesh(jax.device_count(), (1,))
        if not 0 <= n_static <= cfg.in_channels:
            raise ValueError(
                f"n_static={n_static} must be in [0, in_channels="
                f"{cfg.in_channels}]"
            )
        if cache_level not in ("prelift", "deep"):
            raise ValueError(
                f"cache_level must be 'prelift' or 'deep', got {cache_level!r}"
            )
        self.cfg = cfg
        self.mesh = mesh
        self.model_axis = model_axis
        self.n_static = int(n_static)
        # "prelift": cache stops at the encoder prelift (PR-6 behavior);
        # "deep": also cache the first block's static kept-mode spectra and
        # weight-mixed contribution, serving through the deep-split forward.
        self._cache_level = cache_level
        # Fleet-shared tier consulted on local-cache miss (cache_store):
        # entries a peer replica computed are pulled instead of recomputed.
        self.cache_store = cache_store
        # "auto": own cache when there are static channels; None: disabled
        # (the uncached reference path — same split forward, no reuse); a
        # GeomodelCache instance may be shared across runners/replicas.
        self.cache: Optional[GeomodelCache] = (
            GeomodelCache(cache_bytes) if (cache == "auto" and n_static) else
            cache if isinstance(cache, GeomodelCache) else None
        )
        # Fused Pallas serving: params are frozen, so the re/im plane
        # layout of w_spec is computed ONCE here (weight-plane cache) and
        # the complex original is dropped — every block of every rollout
        # step reuses the same planes instead of re-splitting.
        self._planes = bool(cfg.use_pallas)
        forward, x_spec, p_specs = forward_and_specs(
            mesh, cfg, dp_axes=("data",), model_axis=model_axis,
            planes=self._planes,
        )
        self._n_dp = mesh.shape["data"]
        self.buckets = (
            tuple(sorted(set(buckets)))
            if buckets
            else _bucket_ladder(max_slots, self._n_dp)
        )
        for b in self.buckets:
            if b % self._n_dp:
                raise ValueError(
                    f"bucket {b} not divisible by data-parallel size "
                    f"{self._n_dp} (buckets: {self.buckets})"
                )
        if self.buckets[-1] < max_slots:
            # bucket_for would otherwise blow up MID-SERVING, the first
            # time enough slots fill — validate where the %n_dp check lives
            raise ValueError(
                f"largest bucket {self.buckets[-1]} < max_slots {max_slots}:"
                f" every active-set size up to max_slots needs a covering "
                f"bucket (buckets: {self.buckets})"
            )
        self.max_slots = max_slots

        def ns(spec_tree):
            return jax.tree.map(
                lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
                spec_tree,
                is_leaf=lambda s: isinstance(s, P),
            )

        self._x_sharding = NamedSharding(mesh, x_spec)
        # host copy of the encoder weights: cache misses compute the static
        # prelift on host (numpy), deterministically — cold and warm paths
        # feed the SAME arrays into the same jitted forward, so cached
        # serving is bit-identical to uncached serving
        self._enc_w = np.asarray(jax.device_get(params["encoder"]["w"]), np.float32)
        self._enc_b = np.asarray(jax.device_get(params["encoder"]["b"]), np.float32)
        # deep level: host copy of block 0's spectral weights (taken from
        # the COMPLEX tree, before any planes conversion) for the per-miss
        # numpy spectral prefix
        self._w0 = None
        if n_static and cache_level == "deep":
            self._w0 = np.asarray(
                jax.device_get(params["blocks"]["w_spec"][0])
            ).astype(np.complex64)
        if self._planes:
            params = params_with_planes(params)
        self.params = jax.device_put(params, ns(p_specs))
        # one jit; XLA specializes per bucket shape on first use
        self._forward = jax.jit(
            forward,
            in_shardings=(ns(p_specs), self._x_sharding),
            out_shardings=self._x_sharding,
        )
        self._forward_split = None
        self._forward_deep = None
        if n_static:
            split_fwd, _, _ = split_forward_and_specs(
                mesh, cfg, n_static, dp_axes=("data",), model_axis=model_axis,
                planes=self._planes,
            )
            # pre_static [b, width, ...] and x_dyn [b, c_dyn, ...] share the
            # solution layout (channel dim unsharded)
            self._forward_split = jax.jit(
                split_fwd,
                in_shardings=(ns(p_specs), self._x_sharding, self._x_sharding),
                out_shardings=self._x_sharding,
            )
            if cache_level == "deep":
                deep_fwd, _, c_spec, _ = deep_split_forward_and_specs(
                    mesh, cfg, n_static, dp_axes=("data",),
                    model_axis=model_axis, planes=self._planes,
                )
                self._forward_deep = jax.jit(
                    deep_fwd,
                    in_shardings=(
                        ns(p_specs), NamedSharding(mesh, c_spec),
                        self._x_sharding, self._x_sharding,
                    ),
                    out_shardings=self._x_sharding,
                )
        self.x_normalizer = x_normalizer or Normalizer.from_stats(None)
        self.y_normalizer = y_normalizer or Normalizer.from_stats(None)
        self._x_norm_static = _slice_normalizer(self.x_normalizer, slice(0, n_static))
        self._x_norm_dyn = _slice_normalizer(self.x_normalizer, slice(n_static, None))
        n_dyn = cfg.in_channels - n_static
        self.feedback = feedback or (
            lambda y: default_feedback(y, cfg, n_dyn if n_static else None)
        )
        # per-slot state: the ENCODED current input + remaining rollout
        # steps; with static channels the input splits into a per-slot
        # (key, raw static, dynamic) triple — the prelift itself lives in
        # the cache (or is recomputed per tick when the cache is disabled)
        self._inputs: List[Optional[np.ndarray]] = [None] * max_slots
        self._static_key: List[Optional[str]] = [None] * max_slots
        self._static_raw: List[Optional[np.ndarray]] = [None] * max_slots
        self._dyn: List[Optional[np.ndarray]] = [None] * max_slots
        self._remaining: List[int] = [0] * max_slots
        self.batched_steps = 0  # forward launches (vs scenarios served)

    # -- checkpoint loading --------------------------------------------------
    @classmethod
    def from_checkpoint(
        cls,
        ckpt_dir: str,
        *,
        model_shards: Optional[Sequence[int]] = None,
        n_devices: Optional[int] = None,
        step: Optional[int] = None,
        max_slots: int = 4,
        feedback: Optional[Callable] = None,
        n_static: int = 0,
        cache="auto",
        cache_bytes: int = 256 << 20,
        cache_level: str = "deep",
        cache_store: Optional[CacheStore] = None,
        use_pallas: Optional[bool] = None,
        comm_chunks: Optional[int] = None,
    ) -> "FNORunner":
        """Build a runner from a ``train.py --mode fno`` checkpoint dir.

        Reads the ``fno_config.json`` the trainer persists next to its
        checkpoints (architecture + normalization snapshot), restores the
        latest (or ``step``) params re-sharded onto the SERVING mesh —
        which may use a different device count / model-shard layout than
        training did (elastic restore) — and wires the normalizers so
        ingress/egress are in physical units.

        ``use_pallas`` / ``comm_chunks`` default to what training persisted
        (absent in older checkpoints -> unfused, unchunked); pass a value
        to override — the fused and unfused paths are numerically
        equivalent, so a checkpoint trained either way serves either way.
        """
        cfg_path = os.path.join(ckpt_dir, FNO_CONFIG_FILE)
        try:
            with open(cfg_path) as f:
                saved = json.load(f)
        except FileNotFoundError:
            raise FileNotFoundError(
                f"{cfg_path} not found: serve from a checkpoint directory "
                f"written by train.py --mode fno (which persists the FNO "
                f"architecture + normalization snapshot there)"
            ) from None
        cfg = FNOConfig(
            grid=tuple(saved["grid"]),
            modes=tuple(saved["modes"]),
            width=saved["width"],
            in_channels=saved["in_channels"],
            out_channels=saved["out_channels"],
            n_blocks=saved["n_blocks"],
            decoder_dim=saved["decoder_dim"],
            use_pallas=bool(
                saved.get("use_pallas", False) if use_pallas is None
                else use_pallas
            ),
            comm_chunks=int(
                saved.get("comm_chunks", 1) if comm_chunks is None
                else comm_chunks
            ),
        )
        shards = tuple(model_shards or saved.get("model_shards") or (1,))
        mesh, model_axis, _ = build_fno_mesh(
            n_devices if n_devices is not None else jax.device_count(), shards
        )
        from repro.core.fno import param_specs  # specs on the SERVING mesh

        abstract = jax.eval_shape(
            lambda: {"params": init_params(jax.random.PRNGKey(0), cfg)}
        )
        shardings = {
            "params": jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                param_specs(mesh, model_axis),
                is_leaf=lambda s: isinstance(s, P),
            )
        }
        restored, ck_step, _ = ckpt_lib.restore(
            ckpt_dir, abstract, step=step, shardings=shardings
        )
        kind = saved.get("normalizer", "meanstd")
        ndim = len(cfg.grid) + 2
        normalized = saved.get("normalized", [])
        x_norm = (
            Normalizer.from_stats(saved.get("x_stats"), kind, ndim)
            if "x" in normalized
            else Normalizer.from_stats(None)
        )
        y_norm = (
            Normalizer.from_stats(saved.get("y_stats"), kind, ndim)
            if "y" in normalized
            else Normalizer.from_stats(None)
        )
        runner = cls(
            cfg,
            restored["params"],
            mesh=mesh,
            model_axis=model_axis,
            max_slots=max_slots,
            x_normalizer=x_norm,
            y_normalizer=y_norm,
            feedback=feedback,
            n_static=n_static,
            cache=cache,
            cache_bytes=cache_bytes,
            cache_level=cache_level,
            cache_store=cache_store,
        )
        runner.restored_step = ck_step
        return runner

    # -- ModelRunner protocol ------------------------------------------------
    def _check_shape(self, x_raw: np.ndarray) -> np.ndarray:
        expected = (self.cfg.in_channels,) + tuple(self.cfg.grid)
        if tuple(x_raw.shape) != expected:
            raise ValueError(
                f"scenario input shape {tuple(x_raw.shape)} != model's "
                f"{expected}"
            )
        return np.asarray(x_raw, np.float32)

    def _encode(self, x_raw: np.ndarray) -> np.ndarray:
        return self.x_normalizer.encode(self._check_shape(x_raw)[None])[0]

    @property
    def cache_version(self) -> str:
        """Checkpoint+config signature namespacing fleet-shared store
        entries: every weight/stat an entry's arrays depend on is part of
        the digest, so replicas serving different checkpoints (or different
        modes/width/level) can never exchange intermediates."""
        if getattr(self, "_cache_version", None) is None:
            import hashlib

            h = hashlib.blake2b(digest_size=16)
            h.update(repr((
                tuple(self.cfg.grid), tuple(self.cfg.modes), self.cfg.width,
                self.cfg.in_channels, self.n_static, self._cache_level,
            )).encode())
            parts = [self._enc_w, self._enc_b]
            norm = self._x_norm_static
            if not norm.identity:
                parts += [norm.mean, norm.scale]
            if self._w0 is not None:
                parts.append(self._w0)
            for a in parts:
                arr = np.ascontiguousarray(np.asarray(a))
                h.update(str(arr.dtype).encode())
                h.update(str(arr.shape).encode())
                h.update(arr)
            self._cache_version = h.hexdigest()
        return self._cache_version

    @staticmethod
    def _np_gelu(x: np.ndarray) -> np.ndarray:
        """jax.nn.gelu's default tanh approximation, in float32 numpy."""
        x = x.astype(np.float32)
        inner = np.float32(0.7978845608028654) * (
            x + np.float32(0.044715) * x * x * x
        )
        return np.float32(0.5) * x * (np.float32(1.0) + np.tanh(inner))

    def _np_spectra(self, prelift: np.ndarray) -> np.ndarray:
        """Truncated kept-mode spectrum of the static first hidden state,
        computed on host: S(GELU(prelift + b)) — the numpy mirror of
        ``core.fno.spectral_prelift``'s first half. Deterministic, so the
        cold path recomputing it per tick stays bit-identical to warm."""
        h = self._np_gelu(prelift + self._enc_b[:, None, None, None, None])
        xf = np.fft.rfft(h, axis=-1)
        xf = np.fft.fftn(xf, axes=(1, 2, 3))
        mx, my, mz, mt = self.cfg.modes
        for ax, m in ((1, mx), (2, my), (3, mz)):
            lo = np.take(xf, range(m), axis=ax)
            hi = np.take(xf, range(xf.shape[ax] - m, xf.shape[ax]), axis=ax)
            xf = np.concatenate([lo, hi], axis=ax)
        xf = xf[..., :mt]
        return np.ascontiguousarray(xf.astype(np.complex64))

    def _np_contribution(self, spectra: np.ndarray) -> np.ndarray:
        """Block 0's static kept-mode contribution W_0 . S(h_static)."""
        return np.ascontiguousarray(
            np.einsum("ixyzt,ioxyzt->oxyzt", spectra, self._w0)
            .astype(np.complex64)
        )

    def _static_entry(self, key: str, x_static_raw: np.ndarray) -> GeomodelEntry:
        """Geomodel intermediates by content, walked level by level.

        Lookup order: local cache -> fleet-shared store (on local miss) ->
        host recompute of whatever levels are missing (each level derives
        from the previous, so a deep-evicted entry re-pays only the
        spectral prefix, not the normalization). Fresh or deepened entries
        are re-published to both tiers. Cache hit with all levels: the
        stored arrays, untouched — and the miss path is deterministic
        numpy, so cold == warm bitwise.
        """
        deep = self._cache_level == "deep"
        entry = None
        from_store = False
        if self.cache is not None:
            entry = self.cache.get(key)
        if entry is None and self.cache_store is not None:
            entry = self.cache_store.get(self.cache_version, key)
            from_store = entry is not None
        fresh = entry is None
        if fresh:
            normalized = self._x_norm_static.encode(
                np.asarray(x_static_raw, np.float32)[None]
            )[0]
            prelift = np.einsum(
                "ixyzt,io->oxyzt", normalized, self._enc_w[: self.n_static]
            ).astype(np.float32)
            entry = GeomodelEntry(key, normalized, prelift)
        grew = False
        if deep and entry.contribution is None:
            if entry.spectra is None:
                entry = dataclasses.replace(
                    entry, spectra=self._np_spectra(entry.prelift)
                )
            entry = dataclasses.replace(
                entry, contribution=self._np_contribution(entry.spectra)
            )
            grew = True
        if self.cache is not None and (fresh or grew or from_store):
            self.cache.put(key, entry)
        if self.cache_store is not None and (fresh or grew):
            self.cache_store.put(self.cache_version, key, entry)
        return entry

    def request_key(self, req: ScenarioRequest):
        """Content key for scheduler dedup: identical input + identical
        rollout length means byte-identical work (XLA outputs are a
        function of batch shape, not co-batched content)."""
        return (content_key(np.asarray(req.x, np.float32)), int(req.steps))

    def fanout(self, primary: ScenarioRequest, follower: ScenarioRequest) -> None:
        """Give a deduped follower the primary's outputs (shared arrays —
        served outputs are treated as read-only)."""
        follower.outputs = list(primary.outputs)

    def affinity_key(self, req: ScenarioRequest) -> Optional[str]:
        """Fleet cache-affinity key: the content hash of the GEOMODEL only
        (the static channels), not the whole scenario. A gateway routing
        equal keys to the same replica makes that replica's private
        ``GeomodelCache`` hit exactly as a single process would — and keeps
        byte-identical duplicates on one scheduler so in-flight dedup still
        fires. None (no static channels, or an input admit would reject
        anyway) opts the request out of affinity routing."""
        if not self.n_static:
            return None
        x = np.asarray(req.x, np.float32)
        if x.ndim != len(self.cfg.grid) + 1 or x.shape[0] < self.n_static:
            return None
        return content_key(np.ascontiguousarray(x[: self.n_static]))

    def reset(self, req: ScenarioRequest) -> None:
        """Failover resubmission hook: a request pulled off a broken
        replica mid-rollout restarts from its original ``x``, so partial
        outputs are forgotten."""
        req.outputs = []
        req.done = False
        req.error = None

    def admit(self, slot: int, req: ScenarioRequest) -> None:
        if req.steps < 1:
            raise ValueError(f"request {req.rid}: steps must be >= 1")
        if self.n_static:
            x = self._check_shape(req.x)
            static_raw = np.ascontiguousarray(x[: self.n_static])
            # hash once per request; ticks look the entry up by key (the
            # first tick populates the cache on a miss)
            self._static_key[slot] = content_key(static_raw)
            self._static_raw[slot] = static_raw
            self._dyn[slot] = self._x_norm_dyn.encode(x[self.n_static:][None])[0]
        else:
            self._inputs[slot] = self._encode(req.x)
        self._remaining[slot] = int(req.steps)

    def warmup(self) -> float:
        """jit-compile every bucket shape up front (zero batches); returns
        seconds spent, so drivers can report compile time separately from
        steady-state serving throughput."""
        import time as _time

        t0 = _time.perf_counter()
        grid = tuple(self.cfg.grid)
        for b in self.buckets:
            if self.n_static:
                pre = np.zeros((b, self.cfg.width) + grid, np.float32)
                xd = np.zeros(
                    (b, self.cfg.in_channels - self.n_static) + grid, np.float32
                )
                if self._forward_deep is not None:
                    ck = np.zeros(
                        (b, self.cfg.width) + self.cfg.mode_shape, np.complex64
                    )
                    jax.block_until_ready(
                        self._forward_deep(self.params, ck, pre, xd)
                    )
                else:
                    jax.block_until_ready(
                        self._forward_split(self.params, pre, xd)
                    )
            else:
                xb = np.zeros((b, self.cfg.in_channels) + grid, np.float32)
                jax.block_until_ready(self._forward(self.params, xb))
        return _time.perf_counter() - t0

    def bucket_for(self, n_active: int) -> int:
        for b in self.buckets:
            if b >= n_active:
                return b
        raise ValueError(
            f"{n_active} active slots exceed the largest bucket "
            f"{self.buckets[-1]}"
        )

    def step(self, slots: Sequence[Optional[ScenarioRequest]], active: Sequence[int]) -> list:
        bucket = self.bucket_for(len(active))
        grid = tuple(self.cfg.grid)
        if self.n_static:
            # staged per tick = per rollout step: the cache turns the
            # static normalize+prelift into a lookup; without it (cache
            # disabled) each tick recomputes — exactly the pre-cache cost
            pre_b = np.zeros((bucket, self.cfg.width) + grid, np.float32)
            xd_b = np.zeros(
                (bucket, self.cfg.in_channels - self.n_static) + grid, np.float32
            )
            deep = self._forward_deep is not None
            if deep:
                ck_b = np.zeros(
                    (bucket, self.cfg.width) + self.cfg.mode_shape, np.complex64
                )
            for j, i in enumerate(active):
                entry = self._static_entry(self._static_key[i], self._static_raw[i])
                pre_b[j] = entry.prelift
                xd_b[j] = self._dyn[i]
                if deep:
                    ck_b[j] = entry.contribution
            if deep:
                yb = np.asarray(
                    self._forward_deep(self.params, ck_b, pre_b, xd_b)
                )
            else:
                yb = np.asarray(self._forward_split(self.params, pre_b, xd_b))
        else:
            xb = np.zeros((bucket, self.cfg.in_channels) + grid, np.float32)
            for j, i in enumerate(active):
                xb[j] = self._inputs[i]
            yb = np.asarray(self._forward(self.params, xb))
        self.batched_steps += 1
        finished = []
        n_dyn = self.cfg.in_channels - self.n_static
        for j, i in enumerate(active):
            req = slots[i]
            y_raw = self.y_normalizer.decode(yb[j : j + 1])[0]
            req.outputs.append(y_raw)
            self._remaining[i] -= 1
            if self._remaining[i] > 0:
                fb = np.asarray(self.feedback(y_raw), np.float32)
                if self.n_static:
                    # feedback evolves only the DYNAMIC channels; the
                    # geomodel persists (and stays cached) for the slot
                    if tuple(fb.shape) != (n_dyn,) + grid:
                        raise ValueError(
                            f"feedback returned shape {tuple(fb.shape)}; "
                            f"with n_static={self.n_static} it must return "
                            f"the dynamic channels {(n_dyn,) + grid}"
                        )
                    self._dyn[i] = self._x_norm_dyn.encode(fb[None])[0]
                else:
                    self._inputs[i] = self._encode(fb)
            else:
                finished.append(i)
        return finished

    def retire(self, slot: int, req: ScenarioRequest) -> None:
        self._inputs[slot] = None
        self._static_key[slot] = None
        self._static_raw[slot] = None
        self._dyn[slot] = None
        self._remaining[slot] = 0
