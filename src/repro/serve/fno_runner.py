"""PDE-scenario ModelRunner: model-parallel FNO surrogate inference.

The paper's headline result is inference — the trained surrogate simulates
3-D CO2 flow ~5 orders of magnitude faster than the numerical simulator,
which is what makes 1000s-of-scenarios workloads (well-placement
optimization, uncertainty quantification) tractable. This runner serves
that surrogate through the same slot scheduler that serves LLM tokens:

  * one scheduler tick = one batched FNO application over every active
    slot, jit-compiled once per PADDED BUCKET size (active slots are padded
    up to the next bucket so continuous admission doesn't retrigger
    compilation — and, because XLA results are a function of the batch
    SHAPE, a request's output is bit-identical however admission order or
    slot reuse interleaves it with other traffic of the same bucket);
  * the forward is the family's distributed one when the mesh carries model
    axes (paper Alg. 2 / 2-D pencils) — params and batch go through the
    same ``forward_and_specs`` layout contract the training driver uses,
    so a checkpoint trained model-parallel serves model-parallel;
  * ingress applies the store's persisted per-channel normalization (the
    exact stats training normalized with, snapshotted into the
    checkpoint's ``fno_config.json``); egress inverts the target
    normalization, so callers always see physical units;
  * a request may ask for a multi-step autoregressive rollout: the
    de-normalized prediction is fed back through ``feedback`` to build the
    next input (default: repeat the final predicted saturation frame along
    t), re-encoded, and the slot stays busy for the next tick — long-
    horizon forecasts beyond the training window.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.fno import FNOConfig, forward_and_specs, init_params
from repro.data.loader import Normalizer
from repro.launch.mesh import build_fno_mesh
from repro.train import checkpoint as ckpt_lib

FNO_CONFIG_FILE = "fno_config.json"


@dataclasses.dataclass
class ScenarioRequest:
    """One PDE scenario: an input field -> ``steps`` surrogate applications.

    ``x`` is the RAW (physical-units) input ``[c_in, nx, ny, nz, nt]`` —
    e.g. the binary injector map repeated along t. ``outputs`` collects one
    de-normalized prediction ``[c_out, nx, ny, nz, nt]`` per rollout step.
    """

    rid: int
    x: np.ndarray
    steps: int = 1
    outputs: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def prediction(self) -> np.ndarray:
        """Final rollout step's de-normalized prediction."""
        return self.outputs[-1]


def default_feedback(y: np.ndarray, cfg: FNOConfig) -> np.ndarray:
    """Next rollout input from a raw prediction: hold the final predicted
    frame and repeat it along t (the saturation state the next window
    evolves from), tiling/truncating channels to ``in_channels``."""
    nt = cfg.grid[3]
    nxt = np.repeat(y[..., -1:], nt, axis=-1)
    if nxt.shape[0] != cfg.in_channels:
        reps = -(-cfg.in_channels // nxt.shape[0])
        nxt = np.concatenate([nxt] * reps, axis=0)[: cfg.in_channels]
    return np.ascontiguousarray(nxt, np.float32)


def _bucket_ladder(max_slots: int, n_dp: int) -> tuple:
    """Padded-bucket sizes: multiples of the data-parallel size (the batch
    sharding constraint), doubling up to max_slots — so at most
    log2(max_slots/n_dp)+1 jit compilations ever happen."""
    buckets, b = [], n_dp
    while b < max_slots:
        buckets.append(b)
        b *= 2
    buckets.append(max(n_dp, -(-max_slots // n_dp) * n_dp))
    return tuple(sorted(set(buckets)))


class FNORunner:
    """ModelRunner serving batched (data x model)-parallel FNO inference."""

    def __init__(
        self,
        cfg: FNOConfig,
        params,
        *,
        mesh=None,
        model_axis=None,
        max_slots: int = 4,
        x_normalizer: Optional[Normalizer] = None,
        y_normalizer: Optional[Normalizer] = None,
        feedback: Optional[Callable] = None,
        buckets: Optional[Sequence[int]] = None,
    ):
        if mesh is None:
            mesh, model_axis, _ = build_fno_mesh(jax.device_count(), (1,))
        self.cfg = cfg
        self.mesh = mesh
        self.model_axis = model_axis
        forward, x_spec, p_specs = forward_and_specs(
            mesh, cfg, dp_axes=("data",), model_axis=model_axis
        )
        self._n_dp = mesh.shape["data"]
        self.buckets = (
            tuple(sorted(set(buckets)))
            if buckets
            else _bucket_ladder(max_slots, self._n_dp)
        )
        for b in self.buckets:
            if b % self._n_dp:
                raise ValueError(
                    f"bucket {b} not divisible by data-parallel size "
                    f"{self._n_dp} (buckets: {self.buckets})"
                )
        self.max_slots = max_slots

        def ns(spec_tree):
            return jax.tree.map(
                lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
                spec_tree,
                is_leaf=lambda s: isinstance(s, P),
            )

        self._x_sharding = NamedSharding(mesh, x_spec)
        self.params = jax.device_put(params, ns(p_specs))
        # one jit; XLA specializes per bucket shape on first use
        self._forward = jax.jit(
            forward,
            in_shardings=(ns(p_specs), self._x_sharding),
            out_shardings=self._x_sharding,
        )
        self.x_normalizer = x_normalizer or Normalizer.from_stats(None)
        self.y_normalizer = y_normalizer or Normalizer.from_stats(None)
        self.feedback = feedback or (lambda y: default_feedback(y, cfg))
        # per-slot state: the ENCODED current input + remaining rollout steps
        self._inputs: List[Optional[np.ndarray]] = [None] * max_slots
        self._remaining: List[int] = [0] * max_slots
        self.batched_steps = 0  # forward launches (vs scenarios served)

    # -- checkpoint loading --------------------------------------------------
    @classmethod
    def from_checkpoint(
        cls,
        ckpt_dir: str,
        *,
        model_shards: Optional[Sequence[int]] = None,
        n_devices: Optional[int] = None,
        step: Optional[int] = None,
        max_slots: int = 4,
        feedback: Optional[Callable] = None,
    ) -> "FNORunner":
        """Build a runner from a ``train.py --mode fno`` checkpoint dir.

        Reads the ``fno_config.json`` the trainer persists next to its
        checkpoints (architecture + normalization snapshot), restores the
        latest (or ``step``) params re-sharded onto the SERVING mesh —
        which may use a different device count / model-shard layout than
        training did (elastic restore) — and wires the normalizers so
        ingress/egress are in physical units.
        """
        cfg_path = os.path.join(ckpt_dir, FNO_CONFIG_FILE)
        try:
            with open(cfg_path) as f:
                saved = json.load(f)
        except FileNotFoundError:
            raise FileNotFoundError(
                f"{cfg_path} not found: serve from a checkpoint directory "
                f"written by train.py --mode fno (which persists the FNO "
                f"architecture + normalization snapshot there)"
            ) from None
        cfg = FNOConfig(
            grid=tuple(saved["grid"]),
            modes=tuple(saved["modes"]),
            width=saved["width"],
            in_channels=saved["in_channels"],
            out_channels=saved["out_channels"],
            n_blocks=saved["n_blocks"],
            decoder_dim=saved["decoder_dim"],
        )
        shards = tuple(model_shards or saved.get("model_shards") or (1,))
        mesh, model_axis, _ = build_fno_mesh(
            n_devices if n_devices is not None else jax.device_count(), shards
        )
        from repro.core.fno import param_specs  # specs on the SERVING mesh

        abstract = jax.eval_shape(
            lambda: {"params": init_params(jax.random.PRNGKey(0), cfg)}
        )
        shardings = {
            "params": jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                param_specs(mesh, model_axis),
                is_leaf=lambda s: isinstance(s, P),
            )
        }
        restored, ck_step, _ = ckpt_lib.restore(
            ckpt_dir, abstract, step=step, shardings=shardings
        )
        kind = saved.get("normalizer", "meanstd")
        ndim = len(cfg.grid) + 2
        normalized = saved.get("normalized", [])
        x_norm = (
            Normalizer.from_stats(saved.get("x_stats"), kind, ndim)
            if "x" in normalized
            else Normalizer.from_stats(None)
        )
        y_norm = (
            Normalizer.from_stats(saved.get("y_stats"), kind, ndim)
            if "y" in normalized
            else Normalizer.from_stats(None)
        )
        runner = cls(
            cfg,
            restored["params"],
            mesh=mesh,
            model_axis=model_axis,
            max_slots=max_slots,
            x_normalizer=x_norm,
            y_normalizer=y_norm,
            feedback=feedback,
        )
        runner.restored_step = ck_step
        return runner

    # -- ModelRunner protocol ------------------------------------------------
    def _encode(self, x_raw: np.ndarray) -> np.ndarray:
        expected = (self.cfg.in_channels,) + tuple(self.cfg.grid)
        if tuple(x_raw.shape) != expected:
            raise ValueError(
                f"scenario input shape {tuple(x_raw.shape)} != model's "
                f"{expected}"
            )
        return self.x_normalizer.encode(np.asarray(x_raw, np.float32)[None])[0]

    def admit(self, slot: int, req: ScenarioRequest) -> None:
        if req.steps < 1:
            raise ValueError(f"request {req.rid}: steps must be >= 1")
        self._inputs[slot] = self._encode(req.x)
        self._remaining[slot] = int(req.steps)

    def warmup(self) -> float:
        """jit-compile every bucket shape up front (zero batches); returns
        seconds spent, so drivers can report compile time separately from
        steady-state serving throughput."""
        import time as _time

        t0 = _time.perf_counter()
        for b in self.buckets:
            xb = np.zeros(
                (b, self.cfg.in_channels) + tuple(self.cfg.grid), np.float32
            )
            jax.block_until_ready(self._forward(self.params, xb))
        return _time.perf_counter() - t0

    def bucket_for(self, n_active: int) -> int:
        for b in self.buckets:
            if b >= n_active:
                return b
        raise ValueError(
            f"{n_active} active slots exceed the largest bucket "
            f"{self.buckets[-1]}"
        )

    def step(self, slots: Sequence[Optional[ScenarioRequest]], active: Sequence[int]) -> list:
        bucket = self.bucket_for(len(active))
        xb = np.zeros(
            (bucket, self.cfg.in_channels) + tuple(self.cfg.grid), np.float32
        )
        for j, i in enumerate(active):
            xb[j] = self._inputs[i]
        yb = np.asarray(self._forward(self.params, xb))
        self.batched_steps += 1
        finished = []
        for j, i in enumerate(active):
            req = slots[i]
            y_raw = self.y_normalizer.decode(yb[j : j + 1])[0]
            req.outputs.append(y_raw)
            self._remaining[i] -= 1
            if self._remaining[i] > 0:
                self._inputs[i] = self._encode(self.feedback(y_raw))
            else:
                finished.append(i)
        return finished

    def retire(self, slot: int, req: ScenarioRequest) -> None:
        self._inputs[slot] = None
        self._remaining[slot] = 0
