"""Fleet serving through the gateway vs the single-replica baseline.

The production question behind the paper's payoff: one scheduler saturates
one serving mesh — what does a FLEET of replicas buy under an open-loop
arrival process (requests arrive at a fixed rate whether or not the
backlog drains)? This benchmark drives the same UQ-style shared-geomodel
ensemble through

  * one replica (the ``bench_serve.py`` baseline shape: one FNORunner,
    one scheduler), and
  * a 2-replica gateway with cache-affinity routing,

under the SAME arrival schedule, paced at ~4x the measured single-replica
capacity so the baseline saturates. Every tick runs the real scheduler/
runner (real routing, admission, compute, outputs); measured per-tick wall
times compose the timeline on an event clock with one executor per
replica — the deployment model, where each replica is its own serving
host / mesh slice. The CI machine is a single core, so fleet concurrency
cannot show up in wall time; the per-replica-executor accounting follows
the PR-7 precedent (HLO async-collective overlap accounted analytically
where CPU XLA can't express it). The single-shared-executor number — what
THIS host can do — is reported alongside (``one_host_speedup``, ~1.0).

Correctness is part of the contract:

  * single-replica serving through the gateway must be BIT-identical to
    the pre-gateway scheduler path on the same scenario set;
  * the fleet's aggregate geomodel-cache hit-rate under affinity routing
    must match the single-process rate (within 0.05) — scatter routing is
    measured too, as the contrast.
"""
from __future__ import annotations

import time

import numpy as np


def _scenarios(cfg, n, n_geomodels, steps=1):
    """Shared-geomodel UQ ensemble: ``n_geomodels`` distinct permeability
    realizations interleaved across ``n`` scenarios, wells varying."""
    from repro.data.pde.two_phase import TwoPhaseConfig, random_well_mask
    from repro.launch.datagen import geomodel_channel
    from repro.serve import ScenarioRequest

    nx, ny, nz, nt = cfg.grid
    sim_cfg = TwoPhaseConfig(grid=(nx, ny, nz), nt_frames=nt)
    geos = [
        geomodel_channel((nx, ny, nz), nt, seed=g) for g in range(n_geomodels)
    ]
    out = []
    for i in range(n):
        well = np.repeat(
            random_well_mask(sim_cfg, 2, i)[None, :, :, :, None], nt, axis=-1
        ).astype(np.float32)
        x = np.concatenate([geos[i % n_geomodels], well], axis=0)
        out.append(ScenarioRequest(rid=i, x=x, steps=steps))
    return out


def _fresh_caches(runners, cache_bytes=256 << 20):
    from repro.serve import GeomodelCache

    for r in runners:
        r.cache = GeomodelCache(cache_bytes)


def _open_loop(runners, cfg, n, n_geomodels, arrivals, policy,
               per_replica=True, repeats=3):
    """Best of ``repeats`` identical open-loop passes (fresh caches and
    requests each time — routing is deterministic, so every pass sees the
    same fleet state; repeating only damps wall-clock noise in the
    measured per-tick service times)."""
    from repro.serve import Gateway, serve_open_loop

    best = None
    for _ in range(repeats):
        _fresh_caches(runners)
        gw = Gateway(runners, policy=policy)
        requests = _scenarios(cfg, n, n_geomodels)
        report = serve_open_loop(
            gw, requests, arrivals, per_replica_executors=per_replica
        )
        assert report.n_served == n, (report.n_served, n)
        if best is None or report.scen_per_s > best[0].scen_per_s:
            best = (report, gw)
    return best


def run(n_scenarios: int = 48, n_replicas: int = 2, slots: int = 4,
        n_geomodels: int = 2):
    import jax

    from repro.core import FNOConfig, init_params
    from repro.core.partition import make_mesh
    from repro.data.loader import Normalizer
    from repro.serve import FNORunner, Scheduler

    # bench_serve's toy scale with one static geomodel channel; a single
    # fixed bucket so every forward shares one XLA shape (the bit-identity
    # regime) and service times are comparable across passes
    cfg = FNOConfig(
        grid=(8, 8, 4, 4), modes=(2, 2, 2, 2), width=2, in_channels=2,
        n_blocks=1, decoder_dim=8,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    stats = {"mean": [0.2, 0.0], "std": [0.5, 1.0], "absmax": [1.0, 1.0]}

    def make_runner():
        return FNORunner(
            cfg,
            params,
            mesh=make_mesh((1,), ("data",)),
            model_axis=None,
            max_slots=slots,
            buckets=(slots,),
            x_normalizer=Normalizer.from_stats(stats, "meanstd"),
            y_normalizer=Normalizer.from_stats(stats, "meanstd"),
            n_static=1,
        )

    base = make_runner()                      # the single-replica baseline
    fleet = [make_runner() for _ in range(n_replicas)]
    for r in [base] + fleet:
        r.warmup()

    # -- calibrate the arrival rate off measured single-replica capacity --
    # two closed-loop passes, capacity from the best: the first pays
    # residual lazy work (cold geomodel cache, dispatch paths) and would
    # understate capacity, leaving even one replica arrival-limited
    capacity = 0.0
    for _ in range(2):
        _fresh_caches([base])
        t0 = time.perf_counter()
        sched = Scheduler(base, slots)
        for r in _scenarios(cfg, n_scenarios, n_geomodels):
            sched.submit(r)
        done = sched.run_until_done(max_steps=10000)
        assert len(done) == n_scenarios
        capacity = max(capacity, n_scenarios / (time.perf_counter() - t0))
    rate = 8.0 * capacity  # open-loop: arrivals far outpace one replica
    arrivals = [i / rate for i in range(n_scenarios)]

    # -- single replica under the open-loop schedule ----------------------
    single, gw_single = _open_loop(
        [base], cfg, n_scenarios, n_geomodels, arrivals, "least-pending"
    )
    single_hit_rate = gw_single.stats()["fleet"]["cache_hit_rate"]

    # -- the fleet, cache-affinity routing (per-replica executors) --------
    fleet_rep, gw = _open_loop(
        fleet, cfg, n_scenarios, n_geomodels, arrivals, "affinity"
    )
    affinity_hit_rate = gw.stats()["fleet"]["cache_hit_rate"]

    # -- contrast: scatter (least-pending, affinity-blind) ----------------
    _, gw_scatter = _open_loop(
        fleet, cfg, n_scenarios, n_geomodels, arrivals, "least-pending"
    )
    scatter_hit_rate = gw_scatter.stats()["fleet"]["cache_hit_rate"]

    # -- what this one host can do: same fleet, one shared executor -------
    one_host, _ = _open_loop(
        fleet, cfg, n_scenarios, n_geomodels, arrivals, "affinity",
        per_replica=False,
    )

    # -- bit-identity: gateway single-replica == pre-gateway scheduler ----
    from repro.serve import Gateway

    _fresh_caches([base])
    ref_reqs = _scenarios(cfg, n_scenarios, n_geomodels)
    ref_sched = Scheduler(base, slots)
    for r in ref_reqs:
        ref_sched.submit(r)
    ref_sched.run_until_done(max_steps=10000)
    _fresh_caches([base])
    gw_reqs = _scenarios(cfg, n_scenarios, n_geomodels)
    gw1 = Gateway([base])
    for r in gw_reqs:
        gw1.submit(r)
    gw1.run_until_done(max_steps=10000)
    bitwise = all(
        np.array_equal(a.prediction, b.prediction)
        for a, b in zip(ref_reqs, gw_reqs)
    )

    per_scen_us = fleet_rep.makespan_s / n_scenarios * 1e6
    derived = {
        "replicas": n_replicas,
        "single_scen_s": round(single.scen_per_s, 2),
        "fleet_scen_s": round(fleet_rep.scen_per_s, 2),
        "speedup": round(fleet_rep.scen_per_s / single.scen_per_s, 2),
        "one_host_speedup": round(one_host.scen_per_s / single.scen_per_s, 2),
        "p95_single_ms": round(single.percentile(0.95) * 1e3, 2),
        "p95_fleet_ms": round(fleet_rep.percentile(0.95) * 1e3, 2),
        "single_proc_hit_rate": round(single_hit_rate, 3),
        "affinity_hit_rate": round(affinity_hit_rate, 3),
        "hit_rate_gap": round(abs(affinity_hit_rate - single_hit_rate), 3),
        "scatter_hit_rate": round(scatter_hit_rate, 3),
        "bitwise_identical": int(bitwise),
    }
    return per_scen_us, derived


if __name__ == "__main__":
    print(run())
