"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src:. python -m benchmarks.report > artifacts/tables.md
"""
from __future__ import annotations

import json
import os

from benchmarks import roofline
from repro.common.constants import HBM_BYTES_PER_CHIP


def dryrun_table(rows):
    hdr = ("| arch | shape | mesh | compile s | HLO GFLOP/dev | coll GB/dev | "
           "resident GiB/dev | temp GiB (ub) | collective mix |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for d in rows:
        mix = ",".join(
            f"{k.split('-')[-1]}:{v/1e9:.1f}G"
            for k, v in sorted(d["collectives"]["bytes_by_kind"].items(), key=lambda kv: -kv[1])[:3]
        )
        out.append(
            f"| {d['arch']} | {d['shape']} | {'x'.join(str(s) for s in d['mesh']['shape'])} | "
            f"{d['compile_s']:.1f} | {d.get('hlo_flops_loopaware', 0)/1e9:.0f} | "
            f"{d['collectives']['total_bytes']/1e9:.2f} | "
            f"{d['memory'].get('resident_bytes', 0)/2**30:.2f} | "
            f"{d['memory']['temp_bytes']/2**30:.1f} | {mix} |"
        )
    return "\n".join(out)


def roofline_table(rows):
    hdr = ("| arch | shape | compute s | memory s (ub) | collective s | dominant | "
           "MODEL/HLO flops | roofline frac | fits 16 GiB (resident) |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | {r['memory_s']:.2f} | "
            f"{r['collective_s']:.3f} | {r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {'yes' if r['fits_hbm'] else 'NO'} |"
        )
    return "\n".join(out)


def main():
    arts = roofline.load_artifacts()
    arts = [a for a in arts if "_nosp" not in a["_file"]]
    pod = sorted(
        (a for a in arts if a["_file"].endswith("_pod.json")),
        key=lambda a: (a["arch"], a["shape"]),
    )
    multi = sorted(
        (a for a in arts if a["_file"].endswith("_multipod.json")),
        key=lambda a: (a["arch"], a["shape"]),
    )
    print("### Dry-run — single pod 16x16 (256 chips)\n")
    print(dryrun_table(pod))
    print("\n### Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table(multi))
    rows = [roofline.terms(a) for a in pod]
    print("\n### Roofline — single pod (per brief: 16x16 only)\n")
    print(roofline_table(sorted(rows, key=lambda r: (r["arch"], r["shape"]))))


if __name__ == "__main__":
    main()
