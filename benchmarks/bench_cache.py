"""Geomodel content-hash cache: cold vs warm UQ-ensemble serving, per level.

The paper's UQ workload serves an ensemble where every scenario shares the
SAME geomodel (permeability realization) and only the well placement
varies. The static-channel work repeated per scenario per rollout step is
then identical: normalize + encoder prelift (cache level ``prelift``), and
— one level deeper — the first block's static kept-mode spectra and
weight-mixed contribution (level ``deep``, the block-input split of
``fno_forward_deep_split``). ``GeomodelCache`` computes each level once and
replays the stored arrays by content hash. This benchmark serves the same
vary-wells-only ensemble cold (cache disabled) vs warm at BOTH levels over
warm (pre-compiled) runners and reports the per-level throughput ratio —
the deep level must beat the encoder-only speedup, since its cold path
re-pays the spectral prefix too.

Correctness is part of the contract: the cold and warm passes must produce
BITWISE-identical outputs (both run the same split forward fed the same
deterministic host-computed arrays; the cache only changes whether they
are recomputed), asserted request-by-request.

A second section exercises the fleet-shared cache store: two replicas
behind an affinity gateway share a ``DictCacheStore``; the ensemble warms
the pinned replica (and the store), the pinned replica is then broken
mid-wave and the failover re-route lands on the other replica — whose
local cache is cold but whose store lookup HITS, so the geomodel stays
warm fleet-wide. Outputs after failover are asserted bitwise-identical to
the cold reference.
"""
from __future__ import annotations

import time

import numpy as np


def _serve_pass(runner, requests, max_slots):
    from repro.serve import Scheduler

    sched = Scheduler(runner, max_slots)
    for r in requests:
        sched.submit(r)
    t0 = time.perf_counter()
    done = sched.run_until_done(max_steps=10000)
    dt = time.perf_counter() - t0
    assert len(done) == len(requests), (len(done), len(requests))
    return done, dt


def _assert_bitwise(ref_done, got_done, label):
    for rc, rw in zip(ref_done, got_done):
        assert rc.rid == rw.rid and len(rc.outputs) == len(rw.outputs)
        for yc, yw in zip(rc.outputs, rw.outputs):
            if not np.array_equal(np.asarray(yc), np.asarray(yw)):
                raise AssertionError(
                    f"{label}: output differs from cold for rid {rc.rid}"
                )


def run(n_scenarios: int = 16, max_slots: int = 4, rollout_steps: int = 4,
        repeats: int = 3):
    import jax

    from repro.core import FNOConfig, init_params
    from repro.core.partition import make_mesh
    from repro.data.loader import Normalizer
    from repro.launch.serve_pde import build_scenarios
    from repro.serve import DictCacheStore, FNORunner, Gateway, GeomodelCache

    # Geomodel-heavy toy: many static channels on a grid large enough that
    # the per-tick static normalize + prelift + spectral prefix is a
    # visible slice of the tick, next to a deliberately small network —
    # the regime the cache targets (real Sleipner-scale geomodels dwarf
    # the per-step dynamics).
    n_static = 48
    cfg = FNOConfig(
        grid=(32, 16, 8, 8), modes=(2, 2, 2, 2), width=4, n_blocks=1,
        decoder_dim=8, in_channels=n_static + 1,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    x_stats = {
        "mean": np.linspace(0.1, 0.7, cfg.in_channels).tolist(),
        "std": [0.5] * cfg.in_channels,
    }
    y_stats = {"absmax": [1.0] * cfg.out_channels}

    def make_runner(level, cache, store=None):
        return FNORunner(
            cfg,
            params,
            mesh=make_mesh((1,), ("data",)),
            model_axis=None,
            max_slots=max_slots,
            x_normalizer=Normalizer.from_stats(x_stats, "meanstd"),
            y_normalizer=Normalizer.from_stats(y_stats, "absmax"),
            n_static=n_static,
            cache=cache,
            cache_level=level,
            cache_store=store,
        )

    def make_requests():
        reqs, _ = build_scenarios(
            cfg, n_scenarios, wells=1, seed=0, steps=rollout_steps,
            n_static=n_static,
        )
        return reqs

    derived = {}
    level_done = {}
    for level in ("prelift", "deep"):
        cache = GeomodelCache()
        runner = make_runner(level, cache)
        runner.warmup()
        # cold: same forward, same host math — just recomputed every tick
        # (this IS the uncached path the cache must match bitwise); at the
        # deep level the cold path re-pays the spectral prefix too.
        runner.cache = None
        cold = [
            _serve_pass(runner, make_requests(), max_slots)
            for _ in range(repeats)
        ]
        cold_dt = min(dt for _, dt in cold)
        cold_done = cold[-1][0]

        runner.cache = cache
        warm = []
        for _ in range(repeats):
            cache.clear()  # warm from empty: first tick misses, rest hit
            warm.append(_serve_pass(runner, make_requests(), max_slots))
        warm_dt = min(dt for _, dt in warm)
        warm_done = warm[-1][0]
        # hit/miss counters accumulate across passes, but every pass
        # repeats the identical lookup pattern, so the ratio IS per-pass
        stats = cache.stats

        _assert_bitwise(cold_done, warm_done, f"warm[{level}]")
        level_done[level] = cold_done
        derived.update({
            f"cold_scen_s_{level}": round(n_scenarios / cold_dt, 2),
            f"warm_scen_s_{level}": round(n_scenarios / warm_dt, 2),
            f"warm_speedup_{level}": round(cold_dt / warm_dt, 2),
        })
        if level == "deep":
            per_scen_us = warm_dt / n_scenarios * 1e6
            derived.update({
                "warm_speedup": round(cold_dt / warm_dt, 2),
                "hit_rate": round(stats["hit_rate"], 3),
                "cache_entries": stats["entries"],
                "cache_mb": round(stats["bytes"] / 1e6, 2),
            })
    derived["deep_beats_prelift"] = int(
        derived["warm_speedup_deep"] > derived["warm_speedup_prelift"]
    )
    derived["bitwise_identical"] = 1

    # -- fleet-shared store across a failover re-route ----------------------
    store = DictCacheStore()
    runners = [make_runner("deep", GeomodelCache(), store) for _ in range(2)]
    for r in runners:
        r.warmup()
    gateway = Gateway(runners, policy="affinity")
    # wave 1: the shared geomodel pins every scenario to one replica,
    # warming its local cache AND publishing the entry to the store
    for req in make_requests():
        gateway.submit(req)
    wave1 = gateway.run_until_done(max_steps=10000)
    assert len(wave1) == n_scenarios
    pinned = max(gateway.replicas, key=lambda r: r.routed)
    other = next(r for r in gateway.replicas if r is not pinned)
    assert other.routed == 0, "affinity should pin the ensemble to one replica"

    # break the pinned replica: its next scheduler step raises, the
    # gateway fails over and re-routes the in-flight wave to the survivor
    def _dead_step(slots, active):
        raise RuntimeError("simulated replica hardware failure")

    pinned.runner.step = _dead_step
    wave2 = make_requests()
    for req in wave2:
        gateway.submit(req)
    gateway.run_until_done(max_steps=10000)
    assert all(req.done and req.error is None for req in wave2)
    # the survivor's LOCAL cache was cold for this geomodel — the store is
    # what kept it warm fleet-wide
    assert store.hits >= 1, store.stats
    assert other.runner.cache.stats["entries"] >= 1
    _assert_bitwise(level_done["deep"], sorted(wave2, key=lambda r: r.rid),
                    "post-failover")
    fleet = gateway.stats()["fleet"]
    derived.update({
        "store_hits_after_failover": store.hits,
        "store_puts": store.puts,
        "fleet_cache_hit_rate": round(fleet["cache_hit_rate"], 3),
        "fleet_rerouted": fleet["rerouted"],
        "failover_bitwise": 1,
    })
    return per_scen_us, derived


if __name__ == "__main__":
    print(run())
