"""Geomodel content-hash cache: cold vs warm UQ-ensemble serving throughput.

The paper's UQ workload serves an ensemble where every scenario shares the
SAME geomodel (permeability realization) and only the well placement
varies. The static-channel normalize + encoder prelift is then identical
work repeated per scenario per rollout step; ``GeomodelCache`` computes it
once and replays the stored arrays by content hash. This benchmark serves
the same vary-wells-only ensemble twice over ONE warm (pre-compiled)
runner — cache disabled (cold) vs enabled (warm) — and reports the
throughput ratio plus the cache hit-rate.

Correctness is part of the contract: the cold and warm passes must produce
BITWISE-identical outputs (both run the split forward fed the same
deterministic host prelift; the cache only changes whether it is
recomputed), asserted request-by-request.
"""
from __future__ import annotations

import time

import numpy as np


def _serve_pass(runner, requests, max_slots):
    from repro.serve import Scheduler

    sched = Scheduler(runner, max_slots)
    for r in requests:
        sched.submit(r)
    t0 = time.perf_counter()
    done = sched.run_until_done(max_steps=10000)
    dt = time.perf_counter() - t0
    assert len(done) == len(requests), (len(done), len(requests))
    return done, dt


def run(n_scenarios: int = 16, max_slots: int = 4, rollout_steps: int = 4,
        repeats: int = 3):
    import jax

    from repro.core import FNOConfig, init_params
    from repro.core.partition import make_mesh
    from repro.data.loader import Normalizer
    from repro.launch.serve_pde import build_scenarios
    from repro.serve import FNORunner, GeomodelCache

    # Geomodel-heavy toy: many static channels on a grid large enough that
    # the per-tick static normalize + prelift is a visible slice of the
    # tick, next to a deliberately small network — the regime the cache
    # targets (real Sleipner-scale geomodels dwarf the per-step dynamics).
    n_static = 48
    cfg = FNOConfig(
        grid=(32, 16, 8, 8), modes=(2, 2, 2, 2), width=4, n_blocks=1,
        decoder_dim=8, in_channels=n_static + 1,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    x_stats = {
        "mean": np.linspace(0.1, 0.7, cfg.in_channels).tolist(),
        "std": [0.5] * cfg.in_channels,
    }
    y_stats = {"absmax": [1.0] * cfg.out_channels}
    cache = GeomodelCache()
    runner = FNORunner(
        cfg,
        params,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        max_slots=max_slots,
        x_normalizer=Normalizer.from_stats(x_stats, "meanstd"),
        y_normalizer=Normalizer.from_stats(y_stats, "absmax"),
        n_static=n_static,
        cache=cache,
    )
    runner.warmup()

    def make_requests():
        reqs, _ = build_scenarios(
            cfg, n_scenarios, wells=1, seed=0, steps=rollout_steps,
            n_static=n_static,
        )
        return reqs

    # cold: same split forward, same host prelift math — just recomputed
    # every tick (this IS the uncached path the cache must match bitwise)
    runner.cache = None
    cold = [_serve_pass(runner, make_requests(), max_slots) for _ in range(repeats)]
    cold_dt = min(dt for _, dt in cold)
    cold_done = cold[-1][0]

    runner.cache = cache
    warm = []
    for _ in range(repeats):
        cache.clear()  # each pass warms from empty: first tick misses, rest hit
        warm.append(_serve_pass(runner, make_requests(), max_slots))
    warm_dt = min(dt for _, dt in warm)
    warm_done = warm[-1][0]
    # hit/miss counters accumulate across passes, but every pass repeats the
    # identical lookup pattern, so the ratio IS the per-pass hit-rate
    stats = cache.stats

    # bitwise identity, every request, every rollout step
    for rc, rw in zip(cold_done, warm_done):
        assert rc.rid == rw.rid and len(rc.outputs) == len(rw.outputs)
        for yc, yw in zip(rc.outputs, rw.outputs):
            if not np.array_equal(np.asarray(yc), np.asarray(yw)):
                raise AssertionError(
                    f"warm-cache output differs from cold for rid {rc.rid}"
                )

    per_scen_us = warm_dt / n_scenarios * 1e6
    derived = {
        "cold_scen_s": round(n_scenarios / cold_dt, 2),
        "warm_scen_s": round(n_scenarios / warm_dt, 2),
        "warm_speedup": round(cold_dt / warm_dt, 2),
        "hit_rate": round(stats["hit_rate"], 3),
        "cache_entries": stats["entries"],
        "cache_mb": round(stats["bytes"] / 1e6, 2),
        "bitwise_identical": 1,
    }
    return per_scen_us, derived


if __name__ == "__main__":
    print(run())
