"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), in seconds per step, per the brief:
  compute    = HLO_FLOPs(loop-aware, per device) / peak_FLOP/s
  memory     = HLO_bytes(per device)             / HBM_bw
  collective = collective wire bytes(per device) / ICI link bw

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve), the useful-
compute ratio, the dominant term, and a one-line "what would move it".
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

from repro.common.constants import (
    HBM_BANDWIDTH,
    HBM_BYTES_PER_CHIP,
    ICI_BANDWIDTH_PER_LINK,
    PEAK_FLOPS_BF16,
)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_artifacts(art_dir: str = ART_DIR, suffix: Optional[str] = None) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        d["_file"] = os.path.basename(path)
        if suffix is None or d["_file"].endswith(suffix + ".json"):
            out.append(d)
    return out


def terms(d: dict) -> dict:
    n_dev = d["mesh"]["devices"]
    # loop-aware flops are PER DEVICE (the compiled module is the per-device
    # SPMD program); fall back to cost_analysis when the parse found nothing
    flops_dev = max(d.get("hlo_flops_loopaware", 0.0), d.get("hlo_flops", 0.0))
    bytes_dev = max(d.get("hlo_bytes_est", 0.0), d.get("hlo_bytes", 0.0))
    coll_dev = d["collectives"]["total_bytes"]
    overlapped = d["collectives"].get("overlapped_bytes", 0.0)
    t_c = flops_dev / PEAK_FLOPS_BF16
    t_m = bytes_dev / HBM_BANDWIDTH
    t_n = coll_dev / ICI_BANDWIDTH_PER_LINK
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_n), key=lambda kv: kv[1])[0]
    model_flops_dev = d["model_flops"] / n_dev
    useful = model_flops_dev / flops_dev if flops_dev else 0.0
    step_time = max(t_c, t_m, t_n)  # overlap-optimistic bound
    mfu = model_flops_dev / PEAK_FLOPS_BF16 / step_time if step_time else 0.0
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "mesh": "x".join(str(s) for s in d["mesh"]["shape"]),
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        # step-time brackets: a scheduler that can't hide any collective pays
        # t_c + t_n; perfect latency hiding pays max(t_c, t_n). The achieved
        # time lands between them in proportion to the overlapped fraction.
        "serialized_s": t_c + t_n,
        "overlapped_s": max(t_c, t_n),
        "overlap_ratio": overlapped / coll_dev if coll_dev else 0.0,
        "dominant": dominant,
        "model_flops": d["model_flops"],
        "useful_ratio": useful,
        "roofline_frac": mfu,  # MODEL_FLOPS-based fraction of peak at bound
        "peak_gib": d["memory"]["peak_per_device"] / 2**30,
        "resident_gib": d["memory"].get("resident_bytes", 0) / 2**30,
        "fits_hbm": d["memory"].get("resident_bytes", 0) <= HBM_BYTES_PER_CHIP,
        "_file": d["_file"],
    }


_SUGGEST = {
    "compute": "increase arithmetic efficiency (fuse pointwise into matmuls, "
               "larger per-device tiles, reduce remat recompute)",
    "memory": "cut HBM traffic (fuse ops, bf16/int8 storage, smaller "
              "activations via sequence sharding or chunked loss)",
    "collective": "cut wire bytes (truncate-before-repartition, overlap "
                  "collectives with compute, shard to reduce resharding)",
}


def suggestion(row: dict) -> str:
    return _SUGGEST[row["dominant"]]


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "serialized s | overlapped s | overlap | "
           "dominant | model/HLO | roofline frac | resident GiB |")
    sep = "|" + "---|" * 13
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['serialized_s']:.3e} | {r['overlapped_s']:.3e} | "
            f"{r['overlap_ratio']:.2f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['resident_gib']:.1f} |"
        )
    return "\n".join(lines)


def run():
    arts = load_artifacts()
    rows = [terms(d) for d in arts if not d["_file"].endswith("_nosp.json")]
    pod_rows = [r for r in rows if r["mesh"] == "16x16"]
    if not pod_rows:
        return 0.0, {"error": "no dry-run artifacts found; run launch/dryrun first"}
    dominant_counts = {}
    for r in pod_rows:
        dominant_counts[r["dominant"]] = dominant_counts.get(r["dominant"], 0) + 1
    worst = min(pod_rows, key=lambda r: r["roofline_frac"])
    best = max(pod_rows, key=lambda r: r["roofline_frac"])
    derived = {
        "cells": len(pod_rows),
        "dominant_counts": dominant_counts,
        "overlap_ratio_mean": round(
            sum(r["overlap_ratio"] for r in pod_rows) / len(pod_rows), 3
        ),
        "worst": f"{worst['arch']}/{worst['shape']} frac={worst['roofline_frac']:.3f}",
        "best": f"{best['arch']}/{best['shape']} frac={best['roofline_frac']:.3f}",
    }
    return 0.0, derived


if __name__ == "__main__":
    arts = load_artifacts()
    rows = [terms(d) for d in arts if not d["_file"].endswith("_nosp.json")]
    print(markdown_table(sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"]))))
