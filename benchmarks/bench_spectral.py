"""Fused Pallas spectral pipeline: HBM-traffic + overlap accounting.

Three claims of the fused path, measured (toy) and lowered (Sleipner):

1. HBM bytes: the unfused truncate -> mix -> pad pipeline materializes the
   mode tensor three times; the fused kernel streams x, w and y exactly
   once. We read the unfused estimate out of the compiled HLO
   (loop-aware ``collect_compute``) and compare the fused path's analytic
   single-pass bytes.
2. Weight-plane cache: cold (first re/im split) vs warm (dict hit) cost of
   ``cached_weight_planes`` — the per-rollout-step win for serving.
3. All-to-all overlap: ``comm_chunks > 1`` splits every pencil repartition
   into channel chunks so chunk i's wire time hides behind chunk i+1's
   local FFTs. CPU XLA lowers sync collectives only, so the overlap ratio
   is analytic — (c-1)/c once the a2a count in the compiled HLO confirms
   the chunking actually happened — on the toy mesh and on the
   ``fno_sleipner_2d`` pencil config (lower-only, 32 simulated devices).

Persists the full result dict to artifacts/bench/spectral.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
_OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def _run_script(script: str, timeout: int = 900) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout,
    )
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            return json.loads(line[len("RESULT"):])
    raise RuntimeError(proc.stdout + proc.stderr[-2000:])


def _toy_subprocess() -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import dataclasses, json, time
        import jax, jax.numpy as jnp
        from repro.core import FNOConfig, init_params, make_dist_forward
        from repro.core.partition import make_mesh
        from repro.kernels.spectral_conv import (
            cached_weight_planes, clear_plane_cache, spectral_apply_fused,
            spectral_apply_fused_ref,
        )
        from repro.launch import hlo_analysis as ha

        out = {}

        # --- 1. fused vs unfused spectral segment ------------------------
        b, ci, co = 1, 4, 4
        nx, ky, kz, t_in, kt = 8, 4, 4, 5, 3
        trunc, t_out = (nx, None, None), t_in
        key = jax.random.PRNGKey(0)
        ka, kb = jax.random.split(key)
        xf = (jax.random.normal(ka, (b, ci, nx, ky, kz, t_in))
              + 1j * jax.random.normal(kb, (b, ci, nx, ky, kz, t_in))
              ).astype(jnp.complex64)
        w = (jax.random.normal(kb, (ci, co, 4, ky, kz, kt))
             + 1j * jax.random.normal(ka, (ci, co, 4, ky, kz, kt))
             ).astype(jnp.complex64)

        seg = jax.jit(lambda x_, w_: spectral_apply_fused_ref(x_, w_, trunc, t_out))
        hlo = seg.lower(xf, w).compile().as_text()
        unfused_bytes = ha.collect_compute(hlo)["bytes_est"]
        # fused single pass: read x once, read w planes once, write y once
        y_elems = b * co * nx * ky * kz * t_out
        fused_bytes = 8.0 * (xf.size + w.size + y_elems)
        out["unfused_hbm_bytes_est"] = unfused_bytes
        out["fused_hbm_bytes_analytic"] = fused_bytes
        out["hbm_reduction_x"] = unfused_bytes / fused_bytes

        def timed(fn, n=3):
            fn().block_until_ready()  # warmup/compile
            t0 = time.perf_counter()
            for _ in range(n):
                r = fn()
            r.block_until_ready()
            return (time.perf_counter() - t0) / n * 1e6

        out["unfused_us"] = timed(lambda: seg(xf, w))
        out["fused_interpret_us"] = timed(
            lambda: spectral_apply_fused(xf, w, trunc, t_out=t_out, use_pallas=True))

        # --- 2. plane cache cold vs warm ---------------------------------
        clear_plane_cache()
        t0 = time.perf_counter()
        cached_weight_planes(w)[0].block_until_ready()
        out["plane_cache_cold_us"] = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        n = 200
        for _ in range(n):
            cached_weight_planes(w)
        out["plane_cache_warm_us"] = (time.perf_counter() - t0) / n * 1e6

        # --- 3. a2a chunking on the toy pencil meshes --------------------
        cfg = FNOConfig(grid=(32, 32, 16, 16), modes=(4, 4, 2, 3), width=8,
                        n_blocks=1, decoder_dim=8)
        params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        x = jax.ShapeDtypeStruct((1, 1, 32, 32, 16, 16), jnp.float32)
        chunk_rows = {}
        for chunks in (1, 2, 4):
            ccfg = dataclasses.replace(cfg, comm_chunks=chunks)
            fwd = make_dist_forward(make_mesh((1, 8), ("data", "model")),
                                    ccfg, dp_axes=("data",))
            st = ha.collect_collectives(
                jax.jit(fwd).lower(params, x).compile().as_text(), 8)
            chunk_rows[str(chunks)] = {
                "a2a_count": st.count_by_kind.get("all-to-all", 0),
                "a2a_bytes": st.bytes_by_kind.get("all-to-all", 0.0),
                "overlap_ratio_analytic": (chunks - 1) / chunks,
            }
        out["toy_1d_chunking"] = chunk_rows
        print("RESULT" + json.dumps(out))
        """
    ) % (_SRC,)
    return _run_script(script)


def _sleipner_subprocess() -> dict:
    # lower-only on 32 simulated devices (the production 8x4 pencil); the
    # unfused XLA path (use_pallas=False) is what gets compiled — the
    # interpret-mode Pallas kernel would unroll a quarter-million grid
    # steps on this grid. n_blocks reduced 4 -> 1 to bound compile time;
    # collective bytes scale linearly in n_blocks, recorded in the output.
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
        import sys
        sys.path.insert(0, %r)
        import dataclasses, json
        import jax, jax.numpy as jnp
        from repro.configs.fno_sleipner_2d import CONFIG, MODEL_AXES, PENCIL_SHAPE
        from repro.core import init_params, make_dist_forward
        from repro.core.partition import make_mesh
        from repro.launch import hlo_analysis as ha

        cfg = dataclasses.replace(CONFIG, n_blocks=1)
        mesh = make_mesh((1,) + PENCIL_SHAPE, ("data",) + MODEL_AXES)
        params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        x = jax.ShapeDtypeStruct((1, cfg.in_channels) + cfg.grid, jnp.float32)
        out = {"grid": cfg.grid, "pencil": PENCIL_SHAPE, "n_blocks_lowered": 1,
               "n_blocks_full": CONFIG.n_blocks}
        for chunks in (1, 2):
            ccfg = dataclasses.replace(cfg, comm_chunks=chunks)
            fwd = make_dist_forward(mesh, ccfg, dp_axes=("data",),
                                    model_axis=MODEL_AXES)
            st = ha.collect_collectives(
                jax.jit(fwd).lower(params, x).compile().as_text(), 32)
            out["chunks_%%d" %% chunks] = {
                "a2a_count": st.count_by_kind.get("all-to-all", 0),
                "a2a_bytes": st.bytes_by_kind.get("all-to-all", 0.0),
                "total_coll_bytes": st.total_bytes,
                "overlap_ratio_analytic": (chunks - 1) / chunks,
            }
        print("RESULT" + json.dumps(out))
        """
    ) % (_SRC,)
    return _run_script(script, timeout=1800)


def run():
    toy = _toy_subprocess()
    try:
        sleipner = _sleipner_subprocess()
    except Exception as e:  # noqa: BLE001 - the toy rows still stand alone
        sleipner = {"error": repr(e)[:500]}
    result = {"toy": toy, "sleipner_2d": sleipner}
    os.makedirs(_OUT, exist_ok=True)
    with open(os.path.join(_OUT, "spectral.json"), "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    c2 = toy["toy_1d_chunking"].get("2", {})
    derived = {
        "hbm_reduction_x": round(toy["hbm_reduction_x"], 2),
        "plane_cache_cold_us": round(toy["plane_cache_cold_us"], 1),
        "plane_cache_warm_us": round(toy["plane_cache_warm_us"], 2),
        "toy_a2a_count_c1": toy["toy_1d_chunking"]["1"]["a2a_count"],
        "toy_a2a_count_c2": c2.get("a2a_count", 0),
        "overlap_ratio_c2": c2.get("overlap_ratio_analytic", 0.0),
        "sleipner_ok": "error" not in sleipner,
    }
    return toy["fused_interpret_us"], derived
