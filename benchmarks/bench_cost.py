"""Paper §V cost/speedup claims: 5 orders of magnitude faster, 3200x
cheaper per simulation; amortization break-even counts."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cloud.api import SPOT_DISCOUNT, VM_PRICES


def speedup_measured():
    """Measured on THIS machine: numerical simulator vs trained-FNO inference
    on the same grid (architecture-independent ratio of work)."""
    from repro.core import FNOConfig, fno_forward, init_params
    from repro.data.pde.two_phase import TwoPhaseConfig, random_well_mask, simulate

    grid, nt = (16, 8, 8), 4
    cfg_sim = TwoPhaseConfig(grid=grid, nt_frames=nt)
    mask = jnp.asarray(random_well_mask(cfg_sim, 2, 0))
    sim = jax.jit(lambda m: simulate(m, cfg_sim))
    sim(mask).block_until_ready()
    t0 = time.time()
    sim(mask).block_until_ready()
    t_sim = time.time() - t0

    cfg = FNOConfig(grid=grid + (nt,), modes=(4, 2, 2, 2), width=10, n_blocks=3, decoder_dim=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.repeat(np.asarray(mask)[None, None, :, :, :, None], nt, axis=-1), jnp.float32)
    fno = jax.jit(lambda p, xx: fno_forward(p, xx, cfg))
    fno(params, x).block_until_ready()
    t0 = time.time()
    fno(params, x).block_until_ready()
    t_fno = time.time() - t0
    return t_sim, t_fno


def paper_cost_model():
    """The paper's own numbers through our price table."""
    opm_usd = 6.8 * VM_PRICES["E8s_v3"]                  # $3.40
    fno_usd = 0.12 / 3600 * VM_PRICES["ND96amsr"]        # ~$0.0011
    datagen_usd = 1600 * opm_usd                          # ~$5,440 on-demand
    train_usd = 17 * VM_PRICES["ND96amsr"]                # ~$557
    breakeven = (datagen_usd + train_usd) / (opm_usd - fno_usd)
    return {
        "opm_usd_per_sim": round(opm_usd, 2),
        "fno_usd_per_sim": round(fno_usd, 5),
        "cost_ratio": round(opm_usd / fno_usd),
        "datagen_usd": round(datagen_usd),
        "train_usd": round(train_usd),
        "breakeven_sims": round(breakeven),
        "paper_breakeven": 1848,
        "spot_datagen_usd": round(datagen_usd * SPOT_DISCOUNT),
    }


def run():
    t_sim, t_fno = speedup_measured()
    model = paper_cost_model()
    # paper speedup: 6.8 h OPM vs 0.12 s FNO = 2.0e5 (5 orders of magnitude)
    paper_speedup = 6.8 * 3600 / 0.12
    derived = dict(
        model,
        measured_sim_s=round(t_sim, 3),
        measured_fno_s=round(t_fno, 4),
        measured_speedup_x=round(t_sim / max(t_fno, 1e-9), 1),
        paper_speedup_x=round(paper_speedup),
    )
    return t_fno * 1e6, derived
