"""Continuous-batching FNO serving vs sequential single-request serving.

The paper's §V payoff is inference throughput: the trained surrogate
replaces the numerical simulator for 1000s-of-scenario workloads. This
benchmark serves a UQ-style scenario ensemble through the family-generic
scheduler twice over the SAME warm runner — once with a full slot pool
(continuous batching) and once one-request-at-a-time — and reports the
throughput ratio, plus the surrogate-vs-simulator speedup on one reference
scenario (the toy-scale stake in the paper's ~1e5x claim).

Correctness is part of the benchmark contract: every batched, de-normalized
output is replayed through the serial ``fno_forward`` oracle and must match
to float tolerance, else the run fails.
"""
from __future__ import annotations

import time

import numpy as np


def _serve_pass(runner, requests, max_slots):
    from repro.serve import Scheduler

    sched = Scheduler(runner, max_slots)
    for r in requests:
        sched.submit(r)
    t0 = time.perf_counter()
    done = sched.run_until_done(max_steps=10000)
    dt = time.perf_counter() - t0
    assert len(done) == len(requests), (len(done), len(requests))
    return done, dt


def run(n_scenarios: int = 16, max_slots: int = 8, repeats: int = 3):
    import jax

    from repro.core import FNOConfig, init_params
    from repro.core.partition import make_mesh
    from repro.data.loader import Normalizer
    from repro.data.pde.two_phase import TwoPhaseConfig, random_well_mask
    from repro.launch.serve_pde import oracle_rollout
    from repro.serve import FNORunner, ScenarioRequest

    # Toy config sized so per-call dispatch overhead is visible next to
    # compute — the regime continuous batching amortizes. Single-device
    # data mesh: the sequential baseline gets the same hardware.
    cfg = FNOConfig(
        grid=(8, 8, 4, 4), modes=(2, 2, 2, 2), width=2, n_blocks=1,
        decoder_dim=8,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    stats = {"mean": [0.2], "std": [0.5], "absmax": [1.0]}
    runner = FNORunner(
        cfg,
        params,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        max_slots=max_slots,
        x_normalizer=Normalizer.from_stats(stats, "meanstd"),
        y_normalizer=Normalizer.from_stats(stats, "absmax"),
    )
    runner.warmup()

    sim_cfg = TwoPhaseConfig(grid=cfg.grid[:3], nt_frames=cfg.grid[3])

    def make_requests():
        return [
            ScenarioRequest(
                rid=i,
                x=np.repeat(
                    random_well_mask(sim_cfg, 1, i)[None, :, :, :, None],
                    cfg.grid[3],
                    axis=-1,
                ).astype(np.float32),
            )
            for i in range(n_scenarios)
        ]

    # keep the last timed pass's outputs for the oracle check (requests are
    # fresh per pass and outputs are bit-identical across passes anyway)
    batched = [_serve_pass(runner, make_requests(), max_slots) for _ in range(repeats)]
    batched_dt = min(dt for _, dt in batched)
    done = batched[-1][0]
    sequential_dt = min(
        _serve_pass(runner, make_requests(), 1)[1] for _ in range(repeats)
    )

    # batched outputs must match the serial per-request oracle
    max_diff = 0.0
    for r in done:
        (expected,) = oracle_rollout(runner, r.x, 1)
        max_diff = max(max_diff, float(np.abs(r.prediction - expected).max()))
        np.testing.assert_allclose(r.prediction, expected, rtol=1e-5, atol=1e-6)

    # one numerical-simulator reference scenario for the speedup stake
    from repro.data.pde.two_phase import simulate_task

    t0 = time.perf_counter()
    simulate_task(0, 1, sim_cfg.grid, cfg.grid[3])
    sim_s = time.perf_counter() - t0

    per_scen_us = batched_dt / n_scenarios * 1e6
    derived = {
        "batched_scen_s": round(n_scenarios / batched_dt, 2),
        "sequential_scen_s": round(n_scenarios / sequential_dt, 2),
        "batching_speedup": round(sequential_dt / batched_dt, 2),
        "oracle_max_diff": float(max_diff),
        "simulator_s_per_scen": round(sim_s, 3),
        "surrogate_vs_simulator": round(sim_s / (batched_dt / n_scenarios), 0),
    }
    return per_scen_us, derived


if __name__ == "__main__":
    print(run())
