"""Paper Table I analog: FNO surrogate quality on the two applications
(scale-reduced: small grids, hundreds of steps on CPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FNOConfig, fno_forward, init_params, mse_loss
from repro.train import AdamWConfig, adamw_update, init_opt_state, warmup_cosine


def _metrics(pred, y):
    err = np.asarray(pred, np.float64) - np.asarray(y, np.float64)
    mse = float(np.mean(err ** 2))
    mae = float(np.mean(np.abs(err)))
    r2 = 1.0 - np.sum(err ** 2) / np.sum((y - y.mean()) ** 2)
    return {"mse": mse, "mae": mae, "r2": float(r2)}


def _train_eval(x, y, cfg, steps, lr, batch=2):
    n = x.shape[0]
    n_val = max(2, n // 5)
    x_tr, y_tr = x[: n - n_val], y[: n - n_val]
    x_va, y_va = x[n - n_val :], y[n - n_val :]
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=warmup_cosine(lr, 10, steps))

    @jax.jit
    def step(params, opt, bx, by):
        loss, grads = jax.value_and_grad(
            lambda p: mse_loss(fno_forward(p, bx, cfg), by)
        )(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    t0 = time.time()
    for s in range(steps):
        i = (s * batch) % max(x_tr.shape[0] - batch + 1, 1)
        params, opt, loss = step(params, opt, jnp.asarray(x_tr[i : i + batch]), jnp.asarray(y_tr[i : i + batch]))
    train_time = time.time() - t0
    pred = jax.jit(lambda p, xx: fno_forward(p, xx, cfg))(params, jnp.asarray(x_va))
    t1 = time.time()
    pred2 = jax.jit(lambda p, xx: fno_forward(p, xx, cfg))(params, jnp.asarray(x_va))
    jax.block_until_ready(pred2)
    infer_s = time.time() - t1
    m = _metrics(pred, y_va)
    m["final_train_loss"] = float(loss)
    m["train_time_s"] = round(train_time, 1)
    m["infer_s_per_batch"] = round(infer_s, 4)
    return m


def navier_stokes_table(steps=150, n_data=10):
    from repro.data.pde.navier_stokes import simulate_task

    g, nt = 16, 4
    rng = np.random.default_rng(0)
    xs, ys = [], []
    for i in range(n_data):
        chi, vort = simulate_task(tuple(rng.uniform(0.3, 0.7, 3)), n=g, nt=nt)
        xs.append(np.repeat(chi[None, :, :, :, None], nt, axis=-1))
        ys.append(vort[None])
    x = np.stack(xs).astype(np.float32)
    y = np.stack(ys).astype(np.float32)
    y = y / max(np.abs(y).max(), 1e-6)  # normalize target like the paper
    cfg = FNOConfig(grid=(g, g, g, nt), modes=(4, 4, 4, 2), width=10, n_blocks=3, decoder_dim=32)
    return _train_eval(x, y, cfg, steps, lr=2e-3)


def co2_table(steps=150, n_data=10):
    from repro.data.pde.two_phase import simulate_task

    grid, nt = (16, 8, 8), 4
    xs, ys = [], []
    for seed in range(n_data):
        mask, sat = simulate_task(seed, 2, grid, nt)
        xs.append(np.repeat(mask[None, :, :, :, None], nt, axis=-1))
        ys.append(sat[None])
    x = np.stack(xs).astype(np.float32)
    y = np.stack(ys).astype(np.float32)
    cfg = FNOConfig(grid=grid + (nt,), modes=(4, 2, 2, 2), width=10, n_blocks=3, decoder_dim=32)
    return _train_eval(x, y, cfg, steps, lr=2e-3)


def run(steps=500):
    ns = navier_stokes_table(steps, n_data=14)
    co2 = co2_table(steps, n_data=14)
    derived = {
        "navier_stokes": {k: round(v, 5) if isinstance(v, float) else v for k, v in ns.items()},
        "co2": {k: round(v, 5) if isinstance(v, float) else v for k, v in co2.items()},
        "paper_table1": {"ns": {"mse": 0.0507, "r2": 0.9734}, "co2": {"mse": 1.16e-4, "r2": 0.9487}},
    }
    return ns["infer_s_per_batch"] * 1e6, derived
