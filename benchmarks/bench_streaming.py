"""Online vs simulate-then-train: time-to-first-step and steps/s while
generation is in flight.

The paper's adoption cost is that the dataset "must be simulated in
advance"; the streaming path (Meyer-et-al online learning) starts stepping
as soon as the first batch's samples are published. Both arms run the SAME
datagen (two_phase, thread backend) and the same loader/compute; the only
difference is whether training waits for the dataset to finish. "compute"
is a calibrated sleep standing in for the train step, as in bench_loader.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.partition import make_mesh
from repro.data import ArrayStore, ShardedDatasetLoader, StreamingSchedule

N, GRID, NT = 12, (8, 8, 4), 2
BATCH, STEPS = 2, 12
COMPUTE_S = 0.02
SPEC6 = P(("data",), None, None, None, None, None)


def _datagen(out: str) -> None:
    from repro.launch.datagen import main as datagen_main

    datagen_main([
        "--pde", "two_phase", "--n", str(N),
        "--grid", str(GRID[0]), str(GRID[1]), str(GRID[2]), "--nt", str(NT),
        "--out", out, "--backend", "thread", "--workers", "2",
        "--stats-every", "2", "--resume",
    ])


def _wait_store(path: str, timeout: float = 300.0) -> ArrayStore:
    deadline = time.monotonic() + timeout
    while True:
        if os.path.exists(os.path.join(path, "meta.json")):
            store = ArrayStore.open(path)
            if "stats" in store.meta:
                return store
        if time.monotonic() > deadline:
            raise TimeoutError(path)
        time.sleep(0.02)


def _step_loop(loader, first_batch_s: float, t0: float) -> dict:
    for step in range(1, STEPS + 1):
        np.asarray(loader.batch(step)["x"])
        time.sleep(COMPUTE_S)  # the "train step"
    wall = time.monotonic() - t0
    return {
        "t_first_step_s": round(first_batch_s, 4),
        "steps_per_s": round(STEPS / max(wall - first_batch_s, 1e-9), 2),
        "wall_s": round(wall, 4),
    }


def _run_offline(root: str) -> dict:
    mesh = make_mesh((1,), ("data",))
    t0 = time.monotonic()
    _datagen(root)  # simulate-then-train: the whole dataset up front
    xs, ys = ArrayStore.open(f"{root}/x"), ArrayStore.open(f"{root}/y")
    with ShardedDatasetLoader(
        {"x": xs, "y": ys}, mesh, BATCH, {"x": SPEC6, "y": SPEC6},
        normalize=("x",),
    ) as loader:
        np.asarray(loader.batch(0)["x"])
        first = time.monotonic() - t0
        return _step_loop(loader, first, t0)


def _run_online(root: str) -> dict:
    mesh = make_mesh((1,), ("data",))
    t0 = time.monotonic()
    th = threading.Thread(target=_datagen, args=(root,), daemon=True)
    th.start()
    xs = _wait_store(f"{root}/x")
    ys = _wait_store(f"{root}/y")
    schedule = StreamingSchedule([xs, ys], BATCH, seed=0, poll_s=0.005)
    with ShardedDatasetLoader(
        {"x": xs, "y": ys}, mesh, BATCH, {"x": SPEC6, "y": SPEC6},
        normalize=("x",), schedule=schedule,
    ) as loader:
        np.asarray(loader.batch(0)["x"])
        first = time.monotonic() - t0
        out = _step_loop(loader, first, t0)
    th.join()
    out.update(schedule.metrics())
    return out


def run():
    with tempfile.TemporaryDirectory() as d:
        online = _run_online(os.path.join(d, "online"))
        offline = _run_offline(os.path.join(d, "offline"))
    derived = {
        "offline": offline,
        "online": online,
        "first_step_speedup": round(
            offline["t_first_step_s"] / max(online["t_first_step_s"], 1e-9), 2
        ),
        "n_samples": N,
    }
    return online["t_first_step_s"] * 1e6, derived


if __name__ == "__main__":
    import json

    us, derived = run()
    print(f"streaming,{us:.2f},{json.dumps(derived, sort_keys=True)}")
