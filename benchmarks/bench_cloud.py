"""Paper Fig. 4a/4b + Fig. 8: task submission scaling, weak scaling, VM
startup — from the calibrated simulated-cloud backend plus a real (local
process pool) measurement of the API overhead."""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.cloud import BatchPool, SimBackend, SimConfig, ThreadBackend


def _noop(x):
    return x


def submission_scaling():
    """Fig. 4a: submission time vs task count (sim, paper-calibrated) and
    measured per-task submission overhead of our API."""
    sim = SimBackend(SimConfig())
    rows = []
    for n in (4, 16, 64, 256, 1024):
        rep = sim.run_job(n, 64, 60.0)
        rows.append((n, rep.submit_time_s))
    # measured: our object-store + executor submission path
    with tempfile.TemporaryDirectory() as d:
        pool = BatchPool(ThreadBackend(4), store_root=d, n_vms=4)
        t0 = time.time()
        futs = [pool.submit(_noop, (i,)) for i in range(64)]
        submit_elapsed = time.time() - t0
        for f in futs:
            f.result()
        pool.shutdown()
    return {
        "sim_submit_s": rows,
        "sim_submit_1024_s": rows[-1][1],
        "measured_submit_per_task_us": submit_elapsed / 64 * 1e6,
    }


def weak_scaling():
    """Fig. 4b: weak-scaling efficiency for the two datagen workloads."""
    sim = SimBackend(SimConfig())
    out = {}
    for name, n_tasks, runtime in (
        ("navier_stokes_15min", 3200, 15 * 60.0),
        ("co2_6.8h", 1600, 6.8 * 3600.0),
    ):
        effs = []
        for n_vms in (16, 64, 256, 1000):
            rep = sim.run_job(n_tasks, n_vms, runtime)
            effs.append((n_vms, rep.weak_scaling_efficiency(runtime)))
        out[name] = effs
    return out


def vm_startup():
    """Fig. 8a: pool startup distribution (lognormal, calibrated)."""
    sim = SimBackend(SimConfig())
    rep = sim.run_job(1000, 1000, 60.0)
    ready = np.asarray(rep.vm_ready_times)
    return {
        "median_s": float(np.median(ready)),
        "p90_s": float(np.percentile(ready, 90)),
        "frac_up_at_3.5min": float((ready < 210).mean()),
        "frac_up_at_6min": float((ready < 360).mean()),
    }


def run():
    sub = submission_scaling()
    weak = weak_scaling()
    vm = vm_startup()
    derived = {
        "submit_1024_s": round(sub["sim_submit_1024_s"], 1),
        "ns_eff_1000vm": round(dict(weak["navier_stokes_15min"])[1000], 4),
        "co2_eff_1000vm": round(dict(weak["co2_6.8h"])[1000], 4),
        "vm_up_6min": round(vm["frac_up_at_6min"], 3),
    }
    return sub["measured_submit_per_task_us"], derived
