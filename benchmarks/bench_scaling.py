"""Paper Fig. 6 (weak scaling) / Fig. 7 (strong-scaling proxy): domain
decomposition vs pipeline parallelism.

This container's "devices" share one CPU's cores, so wall-clock scaling is
not measurable; instead (per the assignment's dry-run methodology) we lower
both schedules at production scale for P in {2,4,8}, parse per-device FLOPs
and collective wire bytes from the compiled HLO, and project parallel
efficiency under TWO hardware models:

  * A100/NVLink (19.5 TF f32, 600 GB/s) — the paper's testbed. This
    REPRODUCES Fig. 6's contrast (DD > 0.9, PP bubble-bound <= 0.5).
  * TPU v5e/ICI (197 TF bf16, 50 GB/s/link) — our target. The same comm
    volumes are strongly bound by ICI, which motivates the beyond-paper
    comm optimizations in EXPERIMENTS §Perf.

  eff_DD(P) = t_compute / (t_compute + t_comm)
  eff_PP(P) = bubble(M,P) x t_compute / (t_compute + t_comm)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.common.constants import ICI_BANDWIDTH_PER_LINK, PEAK_FLOPS_BF16

A100_PEAK_F32 = 19.5e12
NVLINK_BW = 600e9


def _pencil_shape(p: int) -> tuple:
    """Near-square (px, py) factorization with px*py == p."""
    px = 1
    for cand in range(int(p ** 0.5), 0, -1):
        if p % cand == 0:
            px = p // cand
            break
    return px, p // px


def _measure(p: int, mode: str, nx: int | None = None):
    """Lower DD (1-D x-decomposition), DD2D (pencil) or PP FNO fwd at P
    shards (weak scaling: nx = 32*P unless a fixed nx is given for strong
    scaling), production width/modes; return per-device flops + collective
    bytes."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    px, py = _pencil_shape(p)
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import sys
        sys.path.insert(0, %r)
        import json
        import jax, jax.numpy as jnp
        from repro.core import FNOConfig, init_params, make_dist_forward, make_pipeline_forward
        from repro.core.partition import make_mesh
        from repro.launch import hlo_analysis as ha

        P = %d
        PX, PY = %d, %d
        mode = %r
        nx = %d if %d else 32 * P
        cfg = FNOConfig(grid=(nx, 128, 128, 64), modes=(16, 16, 16, 8),
                        width=40, n_blocks=P if mode == "pp" else 4,
                        decoder_dim=128)
        params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        x = jax.ShapeDtypeStruct((2, 1, nx, 128, 128, 64), jnp.float32)
        if mode == "dd":
            mesh = make_mesh((1, P), ("data", "model"))
            fwd = make_dist_forward(mesh, cfg, dp_axes=("data",))
        elif mode == "dd2d":
            mesh = make_mesh((1, PX, PY), ("data", "mx", "my"))
            fwd = make_dist_forward(mesh, cfg, dp_axes=("data",),
                                    model_axis=("mx", "my"))
        else:
            mesh = make_mesh((1, P), ("data", "model"))
            fwd = make_pipeline_forward(mesh, cfg, n_micro=2)
        hlo = jax.jit(fwd).lower(params, x).compile().as_text()
        comp = ha.collect_compute(hlo)
        coll = ha.collect_collectives(hlo, P)
        print("RESULT" + json.dumps({
            "flops": comp["flops"], "coll_bytes": coll.total_bytes,
            "by_kind": coll.bytes_by_kind,
        }))
        """
    ) % (max(p, 1), src, p, px, py, mode, nx or 0, nx or 0)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=1800
    )
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            return json.loads(line[len("RESULT"):])
    raise RuntimeError(proc.stdout[-1500:] + proc.stderr[-2500:])


def _eff(flops, coll, peak, bw, bubble=1.0):
    t_comp = flops / peak
    t_comm = coll / bw
    return bubble * t_comp / (t_comp + t_comm)


def run():
    rows = []
    for p in (2, 4, 8):
        dd = _measure(p, "dd")
        pp = _measure(p, "pp")
        dd2d = _measure(p, "dd2d") if p >= 4 else None
        bubble = 2 / (2 + p - 1)  # M=2 microbatches (paper's BS=2 case)
        row = {
            "P": p,
            "a100_dd": round(_eff(dd["flops"], dd["coll_bytes"], A100_PEAK_F32, NVLINK_BW), 3),
            "a100_pp": round(_eff(pp["flops"], pp["coll_bytes"], A100_PEAK_F32, NVLINK_BW, bubble), 3),
            "v5e_dd": round(_eff(dd["flops"], dd["coll_bytes"], PEAK_FLOPS_BF16, ICI_BANDWIDTH_PER_LINK), 3),
            "v5e_pp": round(_eff(pp["flops"], pp["coll_bytes"], PEAK_FLOPS_BF16, ICI_BANDWIDTH_PER_LINK, bubble), 3),
            "dd_coll_bytes": dd["coll_bytes"],
            "pp_coll_bytes": pp["coll_bytes"],
        }
        if dd2d is not None:
            # 1-D vs 2-D: same flops (the pencil splits the SAME transform
            # over a (px, py) grid of devices) but two smaller all-to-alls,
            # and crucially no nx/2mx parallelism cap.
            row["a100_dd2d"] = round(
                _eff(dd2d["flops"], dd2d["coll_bytes"], A100_PEAK_F32, NVLINK_BW), 3)
            row["v5e_dd2d"] = round(
                _eff(dd2d["flops"], dd2d["coll_bytes"], PEAK_FLOPS_BF16, ICI_BANDWIDTH_PER_LINK), 3)
            row["dd2d_coll_bytes"] = dd2d["coll_bytes"]
            row["dd2d_mesh"] = list(_pencil_shape(p))
        rows.append(row)
    derived = {
        f"weak_P{r['P']}": {
            k: r[k]
            for k in (
                "a100_dd", "a100_pp", "v5e_dd", "v5e_pp",
                "a100_dd2d", "v5e_dd2d", "dd_coll_bytes", "dd2d_coll_bytes",
            )
            if k in r
        }
        for r in rows
    }
    # Fig. 7: strong scaling — fixed 128^3 x 64 grid, per-device work shrinks
    base = _measure(1, "dd", nx=128)
    t1 = base["flops"] / A100_PEAK_F32
    for p in (2, 4, 8):
        dd = _measure(p, "dd", nx=128)
        tp = dd["flops"] / A100_PEAK_F32 + dd["coll_bytes"] / NVLINK_BW
        derived[f"strong_P{p}_a100_dd_speedup"] = round(t1 / tp, 2)
    derived["paper_claim"] = "A100: weak DD >0.90, PP <=0.50 (Fig. 6); strong DD near-linear (Fig. 7)"
    derived["note"] = "v5e columns motivate §Perf comm optimizations"
    derived["dd2d_note"] = (
        "dd2d = 2-D pencil decomposition (BEYOND-PAPER): lifts the 1-D cap "
        "of nx/2mx devices to (nx/2mx)*(ny/2my); compare dd vs dd2d "
        "coll_bytes at equal P for the comm cost of the second all-to-all"
    )
    return 0.0, derived
