"""Sharded-loader throughput: MB/s off the chunked store and batches/s into
the train step, with the background prefetch on vs off (the overlap win).

The store is synthetic (random fields written through write_sample) so the
benchmark measures the IO + assembly path, not simulation cost. "compute"
is a calibrated sleep standing in for a train step, which is what prefetch
overlaps against.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.partition import make_mesh
from repro.data import ArrayStore, ShardedDatasetLoader

N, C, NX, NY, NZ, NT = 16, 1, 16, 16, 8, 8
BATCH = 4
STEPS = 24
COMPUTE_S = 0.01  # simulated train-step time the prefetch thread can hide


def _build_store(root: str) -> ArrayStore:
    data = np.random.default_rng(0).normal(
        size=(N, C, NX, NY, NZ, NT)
    ).astype(np.float32)
    store = ArrayStore.create(root, data.shape, "f4", (1, C, NX // 2, NY // 2, NZ, NT))
    for i in range(N):
        store.write_sample(i, data[i])
    return store


def _run_epochs(store: ArrayStore, prefetch: int) -> dict:
    mesh = make_mesh((1,), ("data",))
    spec = P(("data",), None, None, None, None, None)
    with ShardedDatasetLoader(
        {"x": store}, mesh, BATCH, {"x": spec}, normalize=(), prefetch=prefetch
    ) as loader:
        loader.batch(0)  # warm the pipeline before timing
        t0 = time.time()
        for step in range(1, STEPS + 1):
            np.asarray(loader.batch(step)["x"])
            time.sleep(COMPUTE_S)  # the "train step" prefetch overlaps
        wall = time.time() - t0
    # MB delivered to the consumer (warmup and prefetch overrun excluded,
    # so prefetch on/off compare the same work)
    mb = STEPS * BATCH * C * NX * NY * NZ * NT * 4 / 1e6
    return {
        "wall_s": round(wall, 4),
        "mb_per_s": round(mb / wall, 2),
        "batches_per_s": round(STEPS / wall, 2),
    }


def run():
    with tempfile.TemporaryDirectory() as d:
        store = _build_store(os.path.join(d, "x"))
        off = _run_epochs(store, prefetch=0)
        on = _run_epochs(store, prefetch=2)
    derived = {
        "prefetch_off": off,
        "prefetch_on": on,
        "overlap_speedup": round(off["wall_s"] / on["wall_s"], 3),
        "batch_mb": round(BATCH * C * NX * NY * NZ * NT * 4 / 1e6, 3),
    }
    us_per_batch = on["wall_s"] / STEPS * 1e6
    return us_per_batch, derived


if __name__ == "__main__":
    import json

    us, derived = run()
    print(f"loader,{us:.2f},{json.dumps(derived, sort_keys=True)}")
