"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (derived = compact JSON).

  fig4_cloud      submission + weak scaling (paper Fig. 4a/4b, Fig. 8)
  fig6_scaling    DD vs PP parallel efficiency projection (Fig. 6/7)
  comm_reduction  truncate-before-repartition bytes (paper §IV-C, ~160x)
  table1_train    FNO surrogate quality, NS + CO2 (Table I, scale-reduced)
  cost_speedup    5-orders speedup + 3200x cost claims (§V)
  roofline        three-term roofline summary over dry-run artifacts
  loader          sharded-loader throughput, prefetch on/off overlap
  streaming       online vs simulate-then-train time-to-first-step
  serve           continuous-batching FNO serving vs sequential + oracle
  cache           geomodel content-hash cache: cold vs warm ensemble serving
  spectral        fused Pallas spectral path: HBM bytes, plane cache, a2a overlap
  gateway         multi-replica fleet vs single replica under open-loop arrivals
"""
from __future__ import annotations

import json
import os
import sys
import traceback

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _persist(name: str, us: float, derived: dict) -> None:
    """Write the suite's result to repo-root ``BENCH_<name>.json`` so the
    perf trajectory is diffable across PRs."""
    path = os.path.join(_REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(
            {"name": name, "us_per_call": round(us, 2), "derived": derived},
            f, indent=2, sort_keys=True,
        )
        f.write("\n")


def main() -> None:
    from benchmarks import (
        bench_cache, bench_cloud, bench_comm, bench_cost, bench_gateway,
        bench_loader, bench_scaling, bench_serve, bench_spectral,
        bench_streaming, bench_train,
    )
    from benchmarks import roofline

    entries = [
        ("fig4_cloud", bench_cloud.run),
        ("fig6_scaling", bench_scaling.run),
        ("comm_reduction", bench_comm.run),
        ("table1_train", bench_train.run),
        ("cost_speedup", bench_cost.run),
        ("roofline", roofline.run),
        ("loader", bench_loader.run),
        ("streaming", bench_streaming.run),
        ("serve", bench_serve.run),
        ("cache", bench_cache.run),
        ("spectral", bench_spectral.run),
        ("gateway", bench_gateway.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for name, fn in entries:
        if only and name != only:
            continue
        try:
            us, derived = fn()
            _persist(name, us, derived)
            print(f"{name},{us:.2f},{json.dumps(derived, sort_keys=True)}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,{{}}  # FAILED")
            traceback.print_exc()
    if failures:
        raise SystemExit(failures)


if __name__ == "__main__":
    main()
