"""Paper §IV-C claim: truncation-before-repartition cuts communicated bytes
per re-partition by ~160x (at the paper's 80%-per-dim truncation).

We lower both schedules (paper Alg. 2 vs Grady et al. [31]) on an 8-way
model mesh and read the actual all-to-all bytes out of the compiled HLO,
then report the measured reduction plus the closed-form factor at both our
and the paper's truncation levels."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap


def _measure_subprocess():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import json
        import jax, jax.numpy as jnp
        from repro.core import FNOConfig, init_params, make_dist_forward
        from repro.core.partition import make_mesh
        from repro.launch import hlo_analysis as ha

        cfg = FNOConfig(grid=(32, 32, 16, 16), modes=(4, 4, 2, 3), width=8,
                        n_blocks=1, decoder_dim=8)
        params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        mesh = make_mesh((1, 8), ("data", "model"))
        x = jax.ShapeDtypeStruct((1, 1, 32, 32, 16, 16), jnp.float32)
        out = {}
        for variant in ("paper", "grady31"):
            fwd = make_dist_forward(mesh, cfg, dp_axes=("data",), variant=variant)
            hlo = jax.jit(fwd).lower(params, x).compile().as_text()
            st = ha.collect_collectives(hlo, 8)
            out[variant] = st.bytes_by_kind
        print("RESULT" + json.dumps(out))
        """
    ) % (src,)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=900
    )
    import json

    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            return json.loads(line[len("RESULT"):])
    raise RuntimeError(proc.stdout + proc.stderr[-2000:])


def closed_form_factor(grid, modes):
    """Full-spectrum vs truncated-spectrum bytes per re-partition."""
    nx, ny, nz, nt = grid
    mx, my, mz, mt = modes
    full = ny * nz * (nt // 2 + 1)
    trunc = (2 * my) * (2 * mz) * mt
    return full / trunc


def run():
    res = _measure_subprocess()
    paper_a2a = res["paper"].get("all-to-all", 0.0)
    grady_a2a = res["grady31"].get("all-to-all", 0.0)
    grady_total = sum(res["grady31"].values())
    paper_total = sum(res["paper"].values())
    measured_ratio = grady_a2a / max(paper_a2a, 1.0)
    bench_cf = closed_form_factor((32, 32, 16, 16), (4, 4, 2, 3))
    # the paper's own truncation (~80% per dim on 130^3 x 84):
    paper_cf = closed_form_factor((130, 130, 130, 84), (13, 13, 13, 9))
    derived = {
        "paper_alg_a2a_bytes": paper_a2a,
        "grady31_a2a_bytes": grady_a2a,
        "measured_reduction_x": round(measured_ratio, 1),
        "closed_form_this_config_x": round(bench_cf, 1),
        "closed_form_paper_truncation_x": round(paper_cf, 1),
        "grady31_total_coll_bytes": grady_total,
        "paper_total_coll_bytes": paper_total,
    }
    return 0.0, derived
