"""Token pipeline: determinism-by-step (the fault supervisor's contract)."""
import tempfile

import numpy as np

from repro.data import ArrayStore, StoreTokens, SyntheticTokens


def test_synthetic_deterministic_and_sharded():
    a = SyntheticTokens(1000, 8, 16, seed=3, host_slice=(0, 2))
    b = SyntheticTokens(1000, 8, 16, seed=3, host_slice=(0, 2))
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    # different steps / hosts differ
    assert not np.array_equal(a.batch(5)["tokens"], a.batch(6)["tokens"])
    other = SyntheticTokens(1000, 8, 16, seed=3, host_slice=(1, 2))
    assert not np.array_equal(a.batch(5)["tokens"], other.batch(5)["tokens"])
    # shapes + shifted targets
    batch = a.batch(0)
    assert batch["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["targets"][:, :-1])
    assert batch["tokens"].max() < 1000


def test_store_tokens_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        rows, row_len = 4, 64
        data = np.arange(rows * row_len, dtype=np.int32).reshape(rows, row_len)
        st = ArrayStore.create(f"{d}/toks", (rows, row_len), "i4", (1, row_len))
        for i in range(rows):
            st.write_chunk((i, 0), data[i : i + 1])
        reader = StoreTokens(f"{d}/toks", seq_len=16, local_batch=3, seed=1)
        b1 = reader.batch(2)
        b2 = reader.batch(2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # every sampled window is a contiguous slice of some row
        for row in b1["tokens"]:
            diffs = np.diff(row)
            assert (diffs == 1).all()
