"""HLO analysis: loop-aware collective/flop accounting on real programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha


def test_trip_count_weighting_flops():
    """Same matmul: scanned 7x must report ~7x the flops of a single call."""
    w = jnp.ones((64, 64))

    def single(x):
        return x @ w

    def scanned(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f1 = ha.collect_compute(jax.jit(single).lower(x).compile().as_text())["flops"]
    f7 = ha.collect_compute(jax.jit(scanned).lower(x).compile().as_text())["flops"]
    assert f1 > 0
    np.testing.assert_allclose(f7 / f1, 7.0, rtol=0.15)


def test_collective_bytes_and_groups():
    """psum over an 8-way axis: all-reduce bytes = 2*size*(g-1)/g."""
    import os, subprocess, sys, textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.common import compat
        from repro.core.partition import make_mesh
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((8,), ("d",))
        fn = compat.shard_map(lambda x: jax.lax.psum(x, "d"), mesh, P("d"), P())
        hlo = jax.jit(fn).lower(jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile().as_text()
        st = ha.collect_collectives(hlo, 8)
        expected = 2 * 1024 * 4 * 7 / 8
        got = st.bytes_by_kind.get("all-reduce", 0)
        assert abs(got - expected) / expected < 0.01, (got, expected)
        print("COLL_OK")
        """
    ) % (os.path.join(os.path.dirname(__file__), "..", "src"),)
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, timeout=300)
    assert "COLL_OK" in proc.stdout, proc.stdout + proc.stderr[-2000:]


def test_shape_bytes_parsing():
    assert ha._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert ha._shape_bytes("bf16[2,3]") == 12
    assert ha._shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert ha._shape_bytes("pred[]") == 0 or ha._shape_bytes("pred[]") == 1


def test_wire_bytes_models():
    assert ha._wire_bytes("all-reduce", 100, 4) == 2 * 100 * 3 / 4
    assert ha._wire_bytes("all-gather", 100, 4) == 100 * 3 / 4
    assert ha._wire_bytes("reduce-scatter", 25, 4) == 25 * 3
    assert ha._wire_bytes("collective-permute", 100, 4) == 100
    assert ha._wire_bytes("all-to-all", 100, 1) == 0
