"""HLO analysis: loop-aware collective/flop accounting on real programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha


def test_trip_count_weighting_flops():
    """Same matmul: scanned 7x must report ~7x the flops of a single call."""
    w = jnp.ones((64, 64))

    def single(x):
        return x @ w

    def scanned(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f1 = ha.collect_compute(jax.jit(single).lower(x).compile().as_text())["flops"]
    f7 = ha.collect_compute(jax.jit(scanned).lower(x).compile().as_text())["flops"]
    assert f1 > 0
    np.testing.assert_allclose(f7 / f1, 7.0, rtol=0.15)


def test_collective_bytes_and_groups():
    """psum over an 8-way axis: all-reduce bytes = 2*size*(g-1)/g."""
    import os, subprocess, sys, textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.common import compat
        from repro.core.partition import make_mesh
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((8,), ("d",))
        fn = compat.shard_map(lambda x: jax.lax.psum(x, "d"), mesh, P("d"), P())
        hlo = jax.jit(fn).lower(jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile().as_text()
        st = ha.collect_collectives(hlo, 8)
        expected = 2 * 1024 * 4 * 7 / 8
        got = st.bytes_by_kind.get("all-reduce", 0)
        assert abs(got - expected) / expected < 0.01, (got, expected)
        print("COLL_OK")
        """
    ) % (os.path.join(os.path.dirname(__file__), "..", "src"),)
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True, timeout=300)
    assert "COLL_OK" in proc.stdout, proc.stdout + proc.stderr[-2000:]


def test_shape_bytes_parsing():
    assert ha._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert ha._shape_bytes("bf16[2,3]") == 12
    assert ha._shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert ha._shape_bytes("pred[]") == 0 or ha._shape_bytes("pred[]") == 1


def test_wire_bytes_models():
    assert ha._wire_bytes("all-reduce", 100, 4) == 2 * 100 * 3 / 4
    assert ha._wire_bytes("all-gather", 100, 4) == 100 * 3 / 4
    assert ha._wire_bytes("reduce-scatter", 25, 4) == 25 * 3
    assert ha._wire_bytes("collective-permute", 100, 4) == 100
    assert ha._wire_bytes("all-to-all", 100, 1) == 0


# CPU XLA only emits sync collectives, so the async -start/-done pairs the
# GPU/TPU latency-hiding scheduler produces are exercised on synthetic HLO.
_ASYNC_HLO = """\
HloModule synthetic

ENTRY %main (p0: f32[8,16]) -> f32[64,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ag-start = (f32[8,16]{1,0}, f32[64,16]{1,0}) all-gather-start(f32[8,16]{1,0} %p0), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ag-done = f32[64,16]{1,0} all-gather-done((f32[8,16]{1,0}, f32[64,16]{1,0}) %ag-start)
  %rs-start = (f32[64,16]{1,0}, f32[8,16]{1,0}) reduce-scatter-start(f32[64,16]{1,0} %ag-done), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %rs-done = f32[8,16]{1,0} reduce-scatter-done((f32[64,16]{1,0}, f32[8,16]{1,0}) %rs-start)
  ROOT %ar = f32[64,16]{1,0} all-reduce(f32[64,16]{1,0} %ag-done), replica_groups={{0,1,2,3,4,5,6,7}}
}
"""


def test_async_start_done_pairs():
    """-start accounted once (output element of the aliasing tuple), -done
    skipped, and async wire bytes land in overlapped_bytes."""
    st = ha.collect_collectives(_ASYNC_HLO, 8)
    ag = 64 * 16 * 4 * 7 / 8           # full gathered output, ring model
    rs = 8 * 16 * 4 * 7                # scattered shard (min tuple element)
    ar = 2 * 64 * 16 * 4 * 7 / 8       # sync all-reduce
    assert st.count_by_kind == {"all-gather": 1, "reduce-scatter": 1, "all-reduce": 1}
    np.testing.assert_allclose(st.bytes_by_kind["all-gather"], ag)
    np.testing.assert_allclose(st.bytes_by_kind["reduce-scatter"], rs)
    np.testing.assert_allclose(st.bytes_by_kind["all-reduce"], ar)
    np.testing.assert_allclose(st.overlapped_bytes, ag + rs)
    np.testing.assert_allclose(st.overlap_fraction, (ag + rs) / (ag + rs + ar))
    assert st.to_dict()["overlapped_bytes"] == st.overlapped_bytes


def test_async_tuple_element_selection():
    assert ha._tuple_elements("(f32[4], f32[8,2]{1,0})") == ["f32[4]", "f32[8,2]{1,0}"]
    assert ha._tuple_elements("f32[4]") == ["f32[4]"]
    # all-gather start: output is the big element; reduce-scatter: the small
    assert ha._async_result_bytes("all-gather", "(f32[8,16], f32[64,16])") == 64 * 16 * 4
    assert ha._async_result_bytes("reduce-scatter", "(f32[64,16], f32[8,16])") == 8 * 16 * 4


def test_roofline_overlap_terms():
    import importlib.util
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "roofline.py")
    spec = importlib.util.spec_from_file_location("_roofline_under_test", path)
    roofline = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(roofline)
    d = {
        "arch": "fno", "shape": "toy", "mesh": {"devices": 8, "shape": [8]},
        "hlo_flops": 1e12, "hlo_bytes": 1e9, "model_flops": 8e11,
        "collectives": {"total_bytes": 1e9, "overlapped_bytes": 5e8},
        "memory": {"peak_per_device": 0},
        "_file": "toy.json",
    }
    r = roofline.terms(d)
    np.testing.assert_allclose(r["serialized_s"], r["compute_s"] + r["collective_s"])
    np.testing.assert_allclose(r["overlapped_s"], max(r["compute_s"], r["collective_s"]))
    np.testing.assert_allclose(r["overlap_ratio"], 0.5)
    # legacy artifacts without overlapped_bytes degrade to ratio 0
    d2 = dict(d, collectives={"total_bytes": 1e9})
    assert roofline.terms(d2)["overlap_ratio"] == 0.0
    assert "overlap" in roofline.markdown_table([r])
