"""Serving subsystem: FNO runner through the family-generic scheduler.

Covers the tentpole contract of the serving refactor:
  * property: scheduler-batched FNO serving is BIT-identical to per-request
    oracle calls under mixed admission order, slot reuse, and padded
    buckets (XLA results are a function of the batch shape, so a fixed
    bucket makes traffic interleaving invisible to each request);
  * the LLM engine regression: the scheduler extraction changed no served
    tokens (multi-request, slot-churn teacher forcing);
  * configurable normalizers (meanstd | absmax) honored by the loader and
    the runner, with persisted absmax stats from datagen;
  * parallel multi-chunk read_slice with exact io_counters;
  * serve_pde end to end from a train.py checkpoint (subprocess CLI).
"""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FNOConfig, fno_forward, init_params
from repro.core.partition import make_mesh
from repro.data import ArrayStore
from repro.data.loader import Normalizer
from repro.serve import FNORunner, ScenarioRequest, Scheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Tiny FNO shared by the property tests; the runner is module-level so its
# jit cache persists across hypothesis examples (slot REUSE across
# schedulers is exactly the serving scenario).
CFG = FNOConfig(
    grid=(8, 4, 4, 2), modes=(2, 2, 2, 1), width=2, n_blocks=2, decoder_dim=4
)
PARAMS = init_params(jax.random.PRNGKey(7), CFG)
BUCKET = 4
STATS = {"mean": [0.1], "std": [0.8], "absmax": [2.0]}


def _make_runner():
    return FNORunner(
        CFG,
        PARAMS,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        max_slots=BUCKET,
        x_normalizer=Normalizer.from_stats(STATS, "meanstd"),
        y_normalizer=Normalizer.from_stats(STATS, "meanstd"),
        buckets=(BUCKET,),
    )


RUNNER = _make_runner()
_ORACLE_FWD = jax.jit(lambda p, x: fno_forward(p, x, CFG))


def _oracle(x_raw: np.ndarray, steps: int):
    """Per-request oracle: serial fno_forward on a zero-padded batch of the
    SAME bucket shape the engine uses (row position / co-batched content
    provably don't affect a row, so this pins the bit pattern)."""
    outs, x = [], np.asarray(x_raw, np.float32)
    for _ in range(steps):
        xb = np.zeros((BUCKET, CFG.in_channels) + CFG.grid, np.float32)
        xb[0] = RUNNER.x_normalizer.encode(x[None])[0]
        y = np.asarray(_ORACLE_FWD(PARAMS, xb))[0]
        y_raw = RUNNER.y_normalizer.decode(y[None])[0]
        outs.append(y_raw)
        x = RUNNER.feedback(y_raw)
    return outs


def _scenario(rid: int, steps: int = 1) -> ScenarioRequest:
    rng = np.random.default_rng(1000 + rid)
    x = rng.normal(size=(CFG.in_channels,) + CFG.grid).astype(np.float32)
    return ScenarioRequest(rid=rid, x=x, steps=steps)


@settings(max_examples=15, deadline=None)
@given(
    n_requests=st.integers(1, 7),
    max_slots=st.integers(1, BUCKET),
    split=st.integers(0, 7),
    steps=st.integers(1, 2),
    interleave=st.integers(0, 3),
)
def test_batched_serving_bit_identical_to_oracle(
    n_requests, max_slots, split, steps, interleave
):
    """Mixed admission order + slot reuse + padded buckets: every request's
    de-normalized outputs are bit-identical to its per-request oracle."""
    sched = Scheduler(RUNNER, max_slots)
    requests = [_scenario(r, steps) for r in range(n_requests)]
    split = min(split, n_requests)
    for r in requests[:split]:
        sched.submit(r)
    # run a few ticks with a partial pool, then admit the rest mid-flight
    for _ in range(interleave):
        sched.step()
    for r in requests[split:]:
        sched.submit(r)
    done = sched.run_until_done(max_steps=500)
    assert sorted(r.rid for r in done) == list(range(n_requests))
    for r in done:
        expected = _oracle(r.x, steps)
        assert len(r.outputs) == steps
        for got, exp in zip(r.outputs, expected):
            np.testing.assert_array_equal(got, exp)


def test_single_request_serving_is_bitwise_fno_forward():
    """A lone request in a size-1 bucket IS the batch-1 serial oracle."""
    runner = FNORunner(
        CFG,
        PARAMS,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        max_slots=1,
        buckets=(1,),
    )
    req = _scenario(0)
    sched = Scheduler(runner, 1)
    sched.submit(req)
    sched.run_until_done()
    expected = np.asarray(_ORACLE_FWD(PARAMS, req.x[None]))[0]
    np.testing.assert_array_equal(req.prediction, expected)


def test_batch1_oracle_matches_to_tolerance():
    """Across DIFFERENT batch shapes XLA only promises numerical closeness;
    the acceptance bound: served outputs match batch-1 fno_forward."""
    from repro.launch.serve_pde import oracle_rollout

    sched = Scheduler(RUNNER, BUCKET)
    requests = [_scenario(r) for r in range(6)]
    for r in requests:
        sched.submit(r)
    sched.run_until_done()
    for r in requests:
        (expected,) = oracle_rollout(RUNNER, r.x, 1)
        np.testing.assert_allclose(r.prediction, expected, rtol=1e-5, atol=1e-6)


def test_rollout_feeds_prediction_back():
    """steps=3 produces 3 outputs, each the oracle of the chained input."""
    sched = Scheduler(RUNNER, 2)
    req = _scenario(0, steps=3)
    sched.submit(req)
    sched.run_until_done()
    assert len(req.outputs) == 3
    for got, exp in zip(req.outputs, _oracle(req.x, 3)):
        np.testing.assert_array_equal(got, exp)


def test_scheduler_reports_latency_and_counts():
    sched = Scheduler(RUNNER, 2)
    reqs = [_scenario(r) for r in range(5)]
    for r in reqs:
        sched.submit(r)
    done = sched.run_until_done()
    assert len(done) == 5 and all(r.done for r in done)
    # 5 requests through 2 slots: at least ceil(5/2) ticks, all timestamped
    assert sched.steps >= 3
    for r in done:
        assert r.finished_s >= r.admitted_s >= r.submitted_s


# ---------------------------------------------------------------------------
# LLM engine regression: the scheduler extraction changed no served tokens.
# ---------------------------------------------------------------------------

def test_llm_tokens_unchanged_with_slot_churn():
    from repro.configs import get_arch, reduced
    from repro.models import init_lm_params, lm_prefill
    from repro.models.policy import LOCAL
    from repro.serve import Engine, Request

    cfg = reduced(get_arch("gemma-7b"))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 9, 2], [7, 1, 3, 4], [2, 8], [6, 6, 1], [9, 3, 5, 2]]
    n_new = [3, 4, 2, 3, 4]

    def teacher_forced(prompt, n):
        seq = list(prompt)
        for _ in range(n):
            logits, _ = jax.jit(lambda p, t: lm_prefill(p, t, cfg, LOCAL))(
                params, jnp.asarray([seq], jnp.int32)
            )
            seq.append(int(jnp.argmax(logits[0])))
        return seq[len(prompt):]

    eng = Engine(cfg, params, max_len=32, max_batch=2)
    reqs = [
        Request(rid=i, prompt=p, max_tokens=n)
        for i, (p, n) in enumerate(zip(prompts, n_new))
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done()
    assert sorted(r.rid for r in done) == list(range(5))
    for r in reqs:
        assert r.output == teacher_forced(r.prompt, len(r.output)), r.rid
    # 5 requests through 2 slots: continuous admission interleaved
    assert eng.steps < sum(n_new)


def test_unservable_family_fails_clearly():
    from repro.configs import get_arch, reduced
    from repro.models import init_lm_params
    from repro.serve import Engine

    cfg = reduced(get_arch("whisper-tiny"))
    with pytest.raises(ValueError, match="not servable.*whisper"):
        Engine(cfg, params=None)


def test_from_checkpoint_missing_config_is_clear():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError, match="fno_config.json"):
            FNORunner.from_checkpoint(d)


# ---------------------------------------------------------------------------
# Configurable normalizers (meanstd | absmax).
# ---------------------------------------------------------------------------

def test_normalizer_roundtrip_and_kinds():
    stats = {"mean": [1.5, -2.0], "std": [0.5, 4.0], "absmax": [3.0, 8.0]}
    x = np.random.default_rng(0).normal(size=(2, 2, 3, 3)).astype(np.float32)
    for kind in ("meanstd", "absmax"):
        n = Normalizer.from_stats(stats, kind, ndim=4)
        np.testing.assert_allclose(n.decode(n.encode(x)), x, rtol=1e-5, atol=1e-6)
    ms = Normalizer.from_stats(stats, "meanstd", ndim=4)
    np.testing.assert_allclose(
        ms.encode(x)[:, 1], (x[:, 1] + 2.0) / 4.0, rtol=1e-6
    )
    am = Normalizer.from_stats(stats, "absmax", ndim=4)
    np.testing.assert_allclose(am.encode(x)[:, 1], x[:, 1] / 8.0, rtol=1e-6)
    with pytest.raises(ValueError, match="unknown normalizer"):
        Normalizer.from_stats(stats, "zscore")
    with pytest.raises(ValueError, match="absmax"):
        Normalizer.from_stats({"mean": [0.0], "std": [1.0]}, "absmax")
    assert Normalizer.from_stats(None).identity


def test_loader_honors_absmax_normalizer():
    from repro.data.loader import ShardedDatasetLoader

    with tempfile.TemporaryDirectory() as d:
        data = np.random.default_rng(1).normal(
            size=(4, 1, 8, 4, 2, 2)
        ).astype(np.float32)
        store = ArrayStore.create(f"{d}/x", data.shape, "f4", (1, 1, 4, 2, 2, 2))
        for i in range(4):
            store.write_sample(i, data[i])
        store.update_meta(
            stats={
                "mean": [float(data.mean())],
                "std": [float(data.std())],
                "absmax": [float(np.abs(data).max())],
            },
            normalizer="absmax",
        )
        mesh = make_mesh((1,), ("data",))
        from jax.sharding import PartitionSpec as P

        loader = ShardedDatasetLoader(
            {"x": ArrayStore.open(f"{d}/x")},
            mesh,
            2,
            {"x": P("data")},
            shuffle=False,
            prefetch=0,
        )
        batch = np.asarray(loader.batch(0)["x"])
        np.testing.assert_allclose(
            batch, data[:2] / np.abs(data).max(), rtol=1e-5, atol=1e-6
        )


def test_datagen_persists_normalizer_and_absmax():
    from repro.launch.datagen import main as datagen

    with tempfile.TemporaryDirectory() as d:
        datagen([
            "--pde", "two_phase", "--n", "2", "--grid", "8", "8", "4",
            "--nt", "2", "--out", f"{d}/ds", "--backend", "thread",
            "--workers", "2", "--normalizer", "absmax",
        ])
        for name in ("x", "y"):
            store = ArrayStore.open(f"{d}/ds/{name}")
            assert store.meta["normalizer"] == "absmax"
            stats = store.meta["stats"]
            full = np.stack([
                store.read_slice(
                    (slice(i, i + 1),) + (slice(None),) * 5
                )[0]
                for i in range(2)
            ])
            np.testing.assert_allclose(
                stats["absmax"], [np.abs(full).max()], rtol=1e-5
            )


# ---------------------------------------------------------------------------
# Parallel multi-chunk read_slice keeps results and io_counters exact.
# ---------------------------------------------------------------------------

def test_read_slice_parallel_exact():
    with tempfile.TemporaryDirectory() as d:
        shape, chunks = (4, 2, 16, 8), (1, 1, 4, 4)
        data = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
        store = ArrayStore.create(f"{d}/s", shape, "f4", chunks)
        for i in range(4):
            store.write_sample(i, data[i])
        sl = (slice(1, 3), slice(0, 2), slice(2, 14), slice(1, 7))
        store.reset_io_counters()
        out = store.read_slice(sl)
        np.testing.assert_array_equal(out, data[sl])
        # exact accounting: 2 samples x 2 channels... chunks are
        # (1,1,4,4): rows 1-2, chans 0-1, x-chunks 0..3, y-chunks 0..1
        expected_chunks = 2 * 2 * 4 * 2
        assert store.io_counters["chunks_read"] == expected_chunks
        assert store.io_counters["bytes_read"] == expected_chunks * 4 * 4 * 4
        # single-chunk reads skip the pool, same counters
        store.reset_io_counters()
        one = store.read_slice((slice(0, 1), slice(0, 1), slice(0, 4), slice(0, 4)))
        np.testing.assert_array_equal(one, data[:1, :1, :4, :4])
        assert store.io_counters["chunks_read"] == 1

        missing = ArrayStore.open(f"{d}/s")
        os.remove(os.path.join(d, "s", "c1_0_1_0"))
        with pytest.raises(FileNotFoundError, match=r"chunk \(1, 0, 1, 0\)"):
            missing.read_slice(sl)


# ---------------------------------------------------------------------------
# serve_pde end to end from a train.py checkpoint (CLI acceptance smoke).
# ---------------------------------------------------------------------------

@pytest.mark.timeout(600)
def test_serve_pde_cli_from_checkpoint(tmp_path):
    env = {**os.environ, "PYTHONPATH": f"{REPO}/src"}
    env.pop("XLA_FLAGS", None)  # single device: the smoke is about wiring
    ds, ck = str(tmp_path / "ds"), str(tmp_path / "ck")
    gen = subprocess.run(
        [sys.executable, "-m", "repro.launch.datagen", "--pde", "two_phase",
         "--n", "4", "--grid", "8", "8", "4", "--nt", "2", "--out", ds,
         "--backend", "thread", "--workers", "2"],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=240,
    )
    assert gen.returncode == 0, gen.stderr
    tr = subprocess.run(
        [sys.executable, f"{REPO}/src/repro/launch/train.py", "--mode", "fno",
         "--x-store", f"{ds}/x", "--y-store", f"{ds}/y", "--steps", "3",
         "--batch", "2", "--width", "4", "--ckpt-dir", ck],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=240,
    )
    assert tr.returncode == 0, tr.stderr
    assert os.path.exists(os.path.join(ck, "fno_config.json"))
    srv = subprocess.run(
        [sys.executable, f"{REPO}/src/repro/launch/serve_pde.py",
         "--ckpt-dir", ck, "--scenarios", "4", "--max-batch", "2",
         "--rollout-steps", "2", "--verify"],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=240,
    )
    assert srv.returncode == 0, srv.stderr + srv.stdout
    assert "verify OK" in srv.stdout, srv.stdout
    assert "served 4 scenarios" in srv.stdout
