"""Property tests for the truncated-FFT operators: 1-D variants (paper /
eager / grady31) and the 2-D pencil decomposition.

Three layers of guarantees:
  * hypothesis-driven dot-product adjoint tests  <F x, y> == <x, F^T y>
    (with the exact rFFT pairing weights) and serial-equivalence over random
    grids/modes, run in-process on a mesh sized to the available devices
    (size-1 axes locally; real all-to-alls under the CI 8-device flag);
  * round-trip identity A(F(x)) == x on the Hermitian-symmetric subspace;
  * a subprocess check on a REAL 2x2 ("mx","my") mesh (4 simulated host
    devices) asserting the acceptance bound: dist_forward_2d/dist_adjoint_2d
    match serial_forward/serial_adjoint to <= 1e-4 relative error.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.common import compat
from repro.core import dfft
from repro.core.partition import CartPartition, make_mesh
from jax.sharding import PartitionSpec as P

from repro.core.dfft import XDIM, YDIM, ZDIM


# ---------------------------------------------------------------------------
# Helpers.
# ---------------------------------------------------------------------------

def _pairing_weights(grid, modes):
    """Diagonal W with <x, A y>_R == Re <W * F(x), y>_C.

    A (= pad + inverse FFT) is the true real-pairing adjoint of F
    (= FFT + truncate) up to the 1/N inverse scaling and the rFFT
    half-spectrum double counting: weight 2 on interior t-bins, 1 on the
    DC bin (and the Nyquist bin when kept).
    """
    nx, ny, nz, nt = grid
    mt = modes[-1]
    wt = np.full((mt,), 2.0, dtype=np.float64)
    wt[0] = 1.0
    if nt % 2 == 0 and mt == nt // 2 + 1:
        wt[-1] = 1.0
    return wt / float(nx * ny * nz * nt)


def _rand_field(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def _rand_spectrum(seed, shape):
    kr, ki = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kr, shape) + 1j * jax.random.normal(ki, shape)).astype(
        jnp.complex64
    )


def _mesh_1d():
    p = 2 if len(jax.devices()) >= 2 else 1
    return make_mesh((p,), ("model",)), p


def _mesh_2d():
    p = 2 if len(jax.devices()) >= 4 else 1
    return make_mesh((p, p), ("mx", "my")), p


_VARIANTS_1D = {
    "paper": (dfft.dist_forward, dfft.dist_adjoint),
    "eager": (dfft.dist_forward_eager, dfft.dist_adjoint_eager),
    "grady31": (dfft.dist_forward_untruncated, dfft.dist_adjoint_untruncated),
}

_VARIANTS_2D = {
    "paper": (dfft.dist_forward_2d, dfft.dist_adjoint_2d),
    "eager": (dfft.dist_forward_2d_eager, dfft.dist_adjoint_2d_eager),
}


def _check_against_serial(fwd, adj, grid, modes, seed, rtol=1e-4):
    """fwd/adj are jit-ed GLOBAL functions (shard_map'd dist or serial)."""
    x = _rand_field(seed, (2, 1) + tuple(grid))
    ref_f = dfft.serial_forward(x, modes)
    got_f = fwd(x)
    scale = float(jnp.max(jnp.abs(ref_f))) or 1.0
    np.testing.assert_allclose(
        np.asarray(got_f), np.asarray(ref_f), rtol=rtol, atol=rtol * scale
    )

    y = _rand_spectrum(seed + 1, ref_f.shape)
    ref_a = dfft.serial_adjoint(y, grid)
    got_a = adj(y)
    scale_a = float(jnp.max(jnp.abs(ref_a))) or 1.0
    np.testing.assert_allclose(
        np.asarray(got_a), np.asarray(ref_a), rtol=rtol, atol=rtol * scale_a
    )

    # dot-product adjoint identity: <x, A y>_R == Re <W * F(x), y>_C
    w = jnp.asarray(_pairing_weights(grid, modes), jnp.float32)
    lhs = float(jnp.vdot(x, got_a).real)
    rhs = complex(jnp.vdot(got_f * w, y)).real
    np.testing.assert_allclose(lhs, rhs, rtol=5e-4, atol=5e-4)

    # round trip: identity on the Hermitian-symmetric subspace (unpaired
    # mode slice of each full-FFT dim zeroed; cf. test_dfft.py).
    mx, my, mz, _ = modes
    spec = ref_f.at[:, :, mx].set(0).at[:, :, :, my].set(0).at[:, :, :, :, mz].set(0)
    xs = dfft.serial_adjoint(spec, grid)
    xs2 = adj(fwd(xs))
    np.testing.assert_allclose(
        np.asarray(xs2), np.asarray(xs), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Hypothesis-driven properties.
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(
    nx=st.sampled_from([8, 16]),
    ny=st.sampled_from([8, 12]),
    m=st.integers(1, 3),
    mt=st.integers(1, 4),
    variant=st.sampled_from(sorted(_VARIANTS_1D)),
)
def test_dist_1d_adjoint_properties(nx, ny, m, mt, variant):
    grid = (nx, ny, 8, 8)
    modes = (min(m, nx // 2), min(m + 1, ny // 2), m, mt)
    mesh, p = _mesh_1d()
    if (2 * modes[1]) % p or nx % p:
        p = 1
        mesh = make_mesh((1,), ("model",))
    fwd_fn, adj_fn = _VARIANTS_1D[variant]
    x_spec = P(None, None, "model", None, None, None)
    f_spec = P(None, None, None, "model", None, None)
    fwd = jax.jit(
        compat.shard_map(lambda a: fwd_fn(a, modes, "model"), mesh, (x_spec,), f_spec)
    )
    adj = jax.jit(
        compat.shard_map(lambda a: adj_fn(a, grid, "model"), mesh, (f_spec,), x_spec)
    )
    _check_against_serial(fwd, adj, grid, modes, seed=nx * 100 + m)


@settings(max_examples=4, deadline=None)
@given(
    nx=st.sampled_from([8, 16]),
    nz=st.sampled_from([4, 8]),
    m=st.integers(1, 2),
    mt=st.integers(1, 3),
    variant=st.sampled_from(sorted(_VARIANTS_2D)),
)
def test_dist_2d_pencil_adjoint_properties(nx, nz, m, mt, variant):
    grid = (nx, 8, nz, 8)
    modes = (min(m + 1, nx // 2), m, min(m, nz // 2), mt)
    mesh, p = _mesh_2d()
    # pencil divisibility: Px | nx, Px | 2my, Py | ny, Py | 2mz
    if nx % p or (2 * modes[1]) % p or 8 % p or (2 * modes[2]) % p:
        p = 1
        mesh = make_mesh((1, 1), ("mx", "my"))
    fwd_fn, adj_fn = _VARIANTS_2D[variant]
    x_spec = P(None, None, "mx", "my", None, None)
    f_spec = P(None, None, None, "mx", "my", None)
    fwd = jax.jit(
        compat.shard_map(
            lambda a: fwd_fn(a, modes, ("mx", "my")), mesh, (x_spec,), f_spec
        )
    )
    adj = jax.jit(
        compat.shard_map(
            lambda a: adj_fn(a, grid, ("mx", "my")), mesh, (f_spec,), x_spec
        )
    )
    _check_against_serial(fwd, adj, grid, modes, seed=nx * 10 + nz)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([8, 12, 16]),
    m=st.integers(1, 4),
    mt=st.integers(1, 4),
)
def test_serial_adjoint_pairing(n, m, mt):
    """<x, A y>_R == Re <W F x, y>_C for the serial oracle itself."""
    grid = (n, 8, 8, 8)
    modes = (min(m, n // 2), min(m, 4), min(m, 4), mt)
    x = _rand_field(n + m, (1, 2) + grid)
    f = dfft.serial_forward(x, modes)
    y = _rand_spectrum(m, f.shape)
    w = jnp.asarray(_pairing_weights(grid, modes), jnp.float32)
    lhs = float(jnp.vdot(x, dfft.serial_adjoint(y, grid)).real)
    rhs = complex(jnp.vdot(f * w, y)).real
    np.testing.assert_allclose(lhs, rhs, rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    src=st.sampled_from([XDIM, YDIM]),
    dst=st.sampled_from([YDIM, ZDIM]),
)
def test_cart_partition_multi_axis_moves(src, dst):
    """with_moved composes per-mesh-axis moves exactly like the pencil path."""
    if src == dst:
        return
    part = CartPartition((None, None, "mx", "my", None, None))
    if part.dims[src] is None:
        return
    axis = part.dims[src]
    moved = part.with_moved(src, dst, axis) if dst != src else part
    assert moved.dims[src] is None
    dst_axes = moved.dims[dst]
    if isinstance(dst_axes, tuple):
        assert axis in dst_axes
    else:
        assert dst_axes == axis
    # moving back restores the original partition
    back = moved.with_moved(dst, src, axis)
    assert back.dims[src] == part.dims[src]
    assert back.dims[dst] == part.dims[dst]


def test_cart_partition_pencil_sequence():
    """The exact partition walk of dist_forward_2d, as descriptor algebra."""
    part = CartPartition((None, None, "mx", "my", None, None))
    after_my = part.with_moved(YDIM, ZDIM, "my")
    assert after_my.dims == (None, None, "mx", None, "my", None)
    after_mx = after_my.with_moved(XDIM, YDIM, "mx")
    assert after_mx.dims == (None, None, None, "mx", "my", None)
    # adjoint path reverses both moves
    back = after_mx.with_moved(YDIM, XDIM, "mx").with_moved(ZDIM, YDIM, "my")
    assert back.dims == part.dims


# ---------------------------------------------------------------------------
# Real 2x2 mesh acceptance check (subprocess: needs 4 simulated devices).
# ---------------------------------------------------------------------------

def test_pencil_2x2_mesh_matches_serial_subprocess():
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, %r)
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.common import compat
        from repro.core import dfft
        from repro.core.partition import make_mesh
        from repro.core.repartition import repartition_multi, repartition_multi_t

        mesh = make_mesh((2, 2), ("mx", "my"))

        # repartition_multi: the pencil move sequence round-trips exactly
        # (each all-to-all is a cross-device permutation; the transposed
        # reversed sequence is its inverse).
        XD, YD, ZD = dfft.XDIM, dfft.YDIM, dfft.ZDIM
        moves = ((YD, ZD, "my"), (XD, YD, "mx"))
        a_spec = P(None, None, "mx", "my", None, None)
        b_spec = P(None, None, None, "mx", "my", None)
        a = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 8, 8, 4, 4))
        fwd_m = jax.jit(compat.shard_map(
            lambda t: repartition_multi(t, moves), mesh, (a_spec,), b_spec))
        bwd_m = jax.jit(compat.shard_map(
            lambda t: repartition_multi_t(t, moves), mesh, (b_spec,), a_spec))
        moved = fwd_m(a)
        np.testing.assert_array_equal(np.asarray(bwd_m(moved)), np.asarray(a))
        # pure permutation: global contents are preserved
        np.testing.assert_allclose(
            float(jnp.vdot(moved, moved)), float(jnp.vdot(a, a)), rtol=1e-6)
        x_spec = P(None, None, "mx", "my", None, None)
        f_spec = P(None, None, None, "mx", "my", None)
        for grid, modes in (((16, 8, 8, 8), (4, 2, 2, 3)),
                            ((8, 16, 4, 6), (2, 3, 2, 2))):
            x = jax.random.normal(jax.random.PRNGKey(0), (2, 2) + grid)
            ref = dfft.serial_forward(x, modes)
            for fwd_fn, adj_fn in (
                (dfft.dist_forward_2d, dfft.dist_adjoint_2d),
                (dfft.dist_forward_2d_eager, dfft.dist_adjoint_2d_eager),
            ):
                fwd = jax.jit(compat.shard_map(
                    lambda a: fwd_fn(a, modes, ("mx", "my")), mesh, (x_spec,), f_spec))
                adj = jax.jit(compat.shard_map(
                    lambda a: adj_fn(a, grid, ("mx", "my")), mesh, (f_spec,), x_spec))
                got = fwd(x)
                rel = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
                assert rel <= 1e-4, (fwd_fn.__name__, grid, rel)
                back_ref = dfft.serial_adjoint(ref, grid)
                back = adj(got)
                rel_a = float(jnp.max(jnp.abs(back - back_ref)) / jnp.max(jnp.abs(back_ref)))
                assert rel_a <= 1e-4, (adj_fn.__name__, grid, rel_a)
        print("PENCIL_2X2_OK")
        """
    ) % (os.path.join(os.path.dirname(__file__), "..", "src"),)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600, env=env,
    )
    assert "PENCIL_2X2_OK" in proc.stdout, proc.stdout + proc.stderr[-3000:]
