"""Sharded-loader assertions, run under 8 simulated host devices.

Executed as a subprocess by test_loader.py (the device-count flag must be
set before jax initializes). Verifies the paper's data-pipeline contract on
a real (data, mx, my) mesh:

  * loader batches are bit-identical to full-materialization reads;
  * each device shard's read touches ONLY the store chunks overlapping its
    (mx, my) pencil — chunk/byte accounting strictly below the dataset;
  * a "process" owning a subset of devices reads strictly fewer bytes than
    the dataset (the multi-host contract, simulated via device_filter);
  * shard_train_step consumes loader batches with matching shardings and
    the loss decreases.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import FNOConfig, init_params, make_dist_forward, mse_loss
from repro.core.fno import input_spec, param_specs
from repro.core.partition import make_mesh
from repro.data import ArrayStore, ShardedDatasetLoader
from repro.train import AdamWConfig, init_opt_state, make_train_step
from repro.train.train_loop import shard_train_step

CHECKS = []


def check(fn):
    CHECKS.append(fn)
    return fn


N, C, NX, NY, NZ, NT = 12, 1, 16, 8, 8, 4
CHUNKS = (1, C, NX // 4, NY // 2, NZ, NT)  # 4 x 2 spatial chunks per sample
BATCH = 4

_tmp = tempfile.TemporaryDirectory()
rng = np.random.default_rng(0)
_x = rng.normal(size=(N, C, NX, NY, NZ, NT)).astype(np.float32)
DATA = {
    "x": _x,
    # learnable target (the train-step check needs the loss to move)
    "y": (np.tanh(np.roll(_x, 1, axis=2)) * 0.5).astype(np.float32),
}
STORES = {}
for key, arr in DATA.items():
    st = ArrayStore.create(os.path.join(_tmp.name, key), arr.shape, "f4", CHUNKS)
    for i in range(N):
        st.write_sample(i, arr[i])
    assert st.n_complete() == N
    STORES[key] = st

MESH = make_mesh((2, 2, 2), ("data", "mx", "my"))
SPEC = input_spec(("data",), ("mx", "my"))
SPECS = {"x": SPEC, "y": SPEC}


def make_loader(**kw):
    kw.setdefault("normalize", ())
    kw.setdefault("prefetch", 0)
    return ShardedDatasetLoader(STORES, MESH, BATCH, SPECS, seed=7, **kw)


@check
def batches_bit_identical_to_full_read():
    """Shard-assembled global batches == full-materialization reference."""
    with make_loader(prefetch=2) as loader:
        for step in (0, 1, 2, 5, 3):  # incl. out-of-order (restart replay)
            batch = loader.batch(step)
            ids = loader.sample_ids(step)
            for key in ("x", "y"):
                np.testing.assert_array_equal(
                    np.asarray(batch[key]), DATA[key][ids]
                )
                assert batch[key].sharding == NamedSharding(MESH, SPECS[key])


@check
def shuffle_covers_every_sample_each_epoch():
    with make_loader() as loader:
        steps_per_epoch = N // BATCH
        ids = np.concatenate(
            [loader.sample_ids(s) for s in range(steps_per_epoch)]
        )
        assert sorted(ids.tolist()) == list(range(N))
        # different epochs, different order; same step, same order
        assert loader.sample_ids(0).tolist() != loader.sample_ids(
            steps_per_epoch
        ).tolist()
        np.testing.assert_array_equal(
            loader.sample_ids(2), make_loader().sample_ids(2)
        )


@check
def shard_reads_touch_only_overlapping_chunks():
    """One device shard's read stays inside its pencil's chunk set."""
    loader = make_loader()
    ids = loader.sample_ids(0)
    store = STORES["x"]
    indices = loader._shard_indices("x")
    assert len(indices) == 8  # every device has a distinct (data, mx, my) cell
    total_chunks = int(np.prod(store.chunk_grid()))
    dataset_bytes = DATA["x"].nbytes
    for index in indices:
        store.reset_io_counters()
        loader._read_shard("x", ids, index)
        got = store.io_counters
        # rows_in_shard x (chunks under one (mx, my) pencil)
        b_rows = index[0].stop - index[0].start
        pencil_chunks = ((NX // 2) // CHUNKS[2]) * ((NY // 2) // CHUNKS[3])
        assert got["chunks_read"] == b_rows * pencil_chunks, (index, got)
        assert got["chunks_read"] < total_chunks
        assert got["bytes_read"] < dataset_bytes, (got, dataset_bytes)
        # bytes are exactly the shard's share: b/2 x 1/(2*2) of a batch
        shard_elems = b_rows * C * (NX // 2) * (NY // 2) * NZ * NT
        assert got["bytes_read"] == shard_elems * 4
    loader.close()


@check
def per_process_bytes_below_dataset():
    """A 'process' owning the (mx=0, my=0) device column reads < dataset."""
    corner = MESH.devices[:, 0, 0].ravel().tolist()
    loader = make_loader(device_filter=lambda d: d in corner)
    store = STORES["x"]
    store.reset_io_counters()
    n_steps = N // BATCH  # one full epoch
    for step in range(n_steps):
        loader._read_host_batch(step)
    got = dict(store.io_counters)
    dataset_bytes = DATA["x"].nbytes
    # the process sees every sample once per epoch but only 1/4 of the
    # spatial volume -> a quarter of the dataset's bytes
    assert got["bytes_read"] == dataset_bytes // 4, (got, dataset_bytes)
    assert got["bytes_read"] < dataset_bytes
    loader.close()


@check
def sharded_train_step_consumes_loader_batches():
    cfg = FNOConfig(
        grid=(NX, NY, NZ, NT), modes=(4, 2, 2, 2), width=6,
        in_channels=C, out_channels=C, n_blocks=2, decoder_dim=12,
    )
    fwd = make_dist_forward(MESH, cfg, dp_axes=("data",), model_axis=("mx", "my"))

    def loss_fn(params, batch):
        return mse_loss(fwd(params, batch["x"]), batch["y"]), {}

    params = init_params(jax.random.PRNGKey(0), cfg)
    abstract = jax.eval_shape(lambda: params)
    p_specs = param_specs(MESH, ("mx", "my"))
    step_fn = make_train_step(loss_fn, AdamWConfig(lr=2e-3), grad_accum=1)
    jit_step = shard_train_step(step_fn, MESH, p_specs, abstract, SPECS)
    opt = init_opt_state(params)
    losses = []
    with make_loader(prefetch=2) as loader:
        for step in range(8):
            params, opt, metrics = jit_step(params, opt, loader.batch(step))
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    # spectral weights actually came out sharded along (ky, kz)
    w = params["blocks"]["w_spec"]
    assert w.sharding.spec == p_specs["blocks"]["w_spec"]


def main():
    for fn in CHECKS:
        fn()
        print(f"ok: {fn.__name__}")
    print("ALL_LOADER_CHECKS_PASSED")


if __name__ == "__main__":
    main()
