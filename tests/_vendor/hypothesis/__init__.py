"""Deterministic fallback for the subset of `hypothesis` this suite uses.

The real hypothesis is declared in requirements-dev.txt and is preferred
whenever importable; conftest.py only puts this package on sys.path when
`import hypothesis` fails (e.g. a hermetic container without the wheel).

Supported surface: @given(**strategies), @settings(max_examples, deadline),
strategies.{integers,floats,booleans,sampled_from,tuples,lists,just,
composite-free map/filter}, assume(), and the settings-above-given or
given-above-settings decoration orders. Examples are drawn from a PRNG
seeded by the test's qualified name, so runs are reproducible; a failing
example is re-raised with the drawn values attached.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

__version__ = "0.0-repro-fallback"

_DEFAULT_MAX_EXAMPLES = 20


class _UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    """Skip the current example when the assumption fails."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred) -> "SearchStrategy":
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _UnsatisfiedAssumption()

        return SearchStrategy(draw)


class _Strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    SearchStrategy = SearchStrategy

    @staticmethod
    def integers(min_value=-(2**31), max_value=2**31) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        if not elements:
            raise ValueError("sampled_from requires a non-empty collection")
        return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def just(value) -> SearchStrategy:
        return SearchStrategy(lambda rng: value)

    @staticmethod
    def tuples(*strats) -> SearchStrategy:
        return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strats))

    @staticmethod
    def lists(elements, min_size=0, max_size=10) -> SearchStrategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]

        return SearchStrategy(draw)


strategies = _Strategies()


class settings:
    """Decorator recording example budget; composes with @given either way."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._hypothesis_settings = self
        return fn


class _HypothesisHandle:
    def __init__(self, inner_test):
        self.inner_test = inner_test


def given(*arg_strats, **kw_strats):
    if arg_strats:
        raise TypeError(
            "the hypothesis fallback supports keyword strategies only; "
            "write @given(x=st.integers(...), ...)"
        )

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_hypothesis_settings", None) or getattr(
                fn, "_hypothesis_settings", None
            )
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode("utf-8")))
            ran = 0
            attempts = 0
            while ran < n and attempts < 50 * n:
                attempts += 1
                drawn = {k: s.draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__}): {drawn!r}"
                    ) from e
                ran += 1
            if ran == 0:
                # Mirror real hypothesis: a test whose assumptions rejected
                # every draw verified nothing and must not pass silently.
                raise AssertionError(
                    f"{fn.__qualname__}: no examples satisfied the "
                    f"assumptions in {attempts} attempts"
                )

        # Pytest plugins (anyio, hypothesis's own) probe fn.hypothesis.inner_test.
        wrapper.hypothesis = _HypothesisHandle(fn)
        # Hide the strategy-filled params from pytest's fixture resolution:
        # the wrapper is called with no arguments, like real hypothesis.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


class HealthCheck:
    """Placeholder matching hypothesis.HealthCheck names used in suppression."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]
