"""Fleet gateway + scheduler-policy regressions.

The bugfix contract of the gateway PR:
  * ``run_until_done``'s ``max_steps`` budgets EACH call, not the
    scheduler's lifetime (a reused scheduler must not spuriously bail);
  * a dedup follower attached to a still-QUEUED primary is admitted when
    the primary is — ``admitted_s`` reflects real queue wait;
  * an all-failed ensemble exits the serving CLI nonzero with per-request
    admit errors, instead of crashing on an empty latency list;
and the gateway properties:
  * single-replica serving through the gateway is BIT-identical to the
    pre-gateway scheduler path, and a 2-replica fleet (same checkpoint,
    fixed bucket) is bit-identical to single-replica serving;
  * a replica whose runner raises mid-flight is failed over — its
    unfinished requests land on healthy replicas, nothing wedges;
  * cache-affinity routing keeps the fleet geomodel-cache hit-rate at the
    single-process rate (scatter routing degrades it);
  * the autoscaling hook spawns on backlog and retires idle replicas;
  * ``serve_open_loop``'s per-replica event clock overlaps replica
    service times (and the shared-executor clock does not).
"""
import json
import os
import subprocess
import sys
import time
import warnings

import numpy as np
import pytest

import jax

from repro.core import FNOConfig, init_params
from repro.core.partition import make_mesh
from repro.data.loader import Normalizer
from repro.serve import (
    FNORunner, Gateway, ScenarioRequest, Scheduler, serve_open_loop,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# tiny FNO with one static (geomodel) channel + one dynamic channel, a
# single fixed bucket so every forward shares one XLA batch shape — the
# regime where serving results are bit-reproducible across interleavings
CFG = FNOConfig(
    grid=(8, 4, 4, 2), modes=(2, 2, 2, 1), width=2, in_channels=2,
    n_blocks=1, decoder_dim=4,
)
PARAMS = init_params(jax.random.PRNGKey(7), CFG)
BUCKET = 4
STATS = {"mean": [0.1, 0.0], "std": [0.8, 1.0], "absmax": [2.0, 1.0]}


def _make_runner(n_static=0):
    return FNORunner(
        CFG,
        PARAMS,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        max_slots=BUCKET,
        x_normalizer=Normalizer.from_stats(STATS, "meanstd"),
        y_normalizer=Normalizer.from_stats(STATS, "meanstd"),
        buckets=(BUCKET,),
        n_static=n_static,
    )


def _scenario(rid, steps=1, geo_seed=None, **kw):
    """Random scenario; ``geo_seed`` pins the first (static) channel to a
    shared geomodel realization so requests can share cache entries."""
    rng = np.random.default_rng(1000 + rid)
    x = rng.normal(size=(CFG.in_channels,) + CFG.grid).astype(np.float32)
    if geo_seed is not None:
        geo_rng = np.random.default_rng(5000 + geo_seed)
        x[0] = geo_rng.normal(size=CFG.grid).astype(np.float32)
    return ScenarioRequest(rid=rid, x=x, steps=steps, **kw)


class DummyRunner:
    """Minimal ModelRunner: each request needs ``work`` steps; optionally
    raises out of ``step`` after ``break_after`` calls (the failover
    trigger), or sleeps ``sleep_s`` per step (the event-clock workload)."""

    def __init__(self, work=1, break_after=None, sleep_s=0.0, max_slots=4):
        self.work = work
        self.break_after = break_after
        self.sleep_s = sleep_s
        self.max_slots = max_slots
        self.calls = 0
        self._left = {}

    def admit(self, slot, request):
        self._left[slot] = getattr(request, "work", self.work)

    def step(self, slots, active):
        self.calls += 1
        if self.break_after is not None and self.calls > self.break_after:
            raise RuntimeError("replica hardware gone")
        if self.sleep_s:
            time.sleep(self.sleep_s)
        done = []
        for i in active:
            self._left[i] -= 1
            if self._left[i] <= 0:
                done.append(i)
        return done

    def retire(self, slot, request):
        self._left.pop(slot, None)

    def reset(self, request):
        request.done = False
        request.error = None


class KeyedDummyRunner(DummyRunner):
    """DummyRunner + content dedup (key = request.key)."""

    def request_key(self, request):
        return getattr(request, "key", None)

    def fanout(self, primary, follower):
        follower.fanned_from = primary.rid


class Req:
    """Bare request object for dummy-runner tests."""

    def __init__(self, rid, work=1, key=None, priority=0, deadline_s=None):
        self.rid = rid
        self.work = work
        self.key = key
        self.priority = priority
        self.deadline_s = deadline_s
        self.done = False
        self.error = None


# -- satellite regressions ---------------------------------------------------

def test_run_until_done_budget_is_per_call():
    """A reused scheduler gets a fresh max_steps budget every call: three
    waves of work whose CUMULATIVE steps exceed the budget must all finish
    without the spurious exhaustion warning the old cumulative comparison
    produced."""
    sched = Scheduler(DummyRunner(work=4), max_slots=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for wave in range(3):
            reqs = [Req(10 * wave + i, work=4) for i in range(2)]
            for r in reqs:
                sched.submit(r)
            sched.run_until_done(max_steps=5)  # < 3 waves x 4 steps
            assert all(r.done for r in reqs), f"wave {wave} unfinished"
    assert sched.steps == 12  # 3 waves x 4 steps each actually ran


def test_run_until_done_warns_when_budget_exhausted():
    sched = Scheduler(DummyRunner(work=10), max_slots=1)
    sched.submit(Req(0, work=10))
    with pytest.warns(RuntimeWarning, match="max_steps=3 exhausted"):
        sched.run_until_done(max_steps=3)


def test_follower_of_queued_primary_admitted_with_primary():
    """A dedup follower attached while its primary is still QUEUED must not
    be stamped admitted at submit — it is admitted when the primary is, so
    latency stats see the real queue wait."""
    sched = Scheduler(KeyedDummyRunner(work=3), max_slots=1)
    blocker = Req(0, work=3, key="blk")
    primary = Req(1, work=3, key="shared")
    sched.submit(blocker)
    sched.step()  # blocker occupies the only slot
    sched.submit(primary)  # queued behind it
    follower = Req(2, work=3, key="shared")
    sched.submit(follower)
    assert sched.dedup_attached == 1
    assert getattr(follower, "admitted_s", None) is None  # THE regression
    sched.run_until_done()
    assert follower.done and follower.fanned_from == 1
    assert follower.admitted_s == primary.admitted_s
    assert follower.submitted_s <= follower.admitted_s <= follower.finished_s
    # latency ordering is now meaningful: queue wait > 0 for both
    assert primary.admitted_s > primary.submitted_s


def test_follower_of_active_primary_admitted_at_submit():
    sched = Scheduler(KeyedDummyRunner(work=3), max_slots=1)
    primary = Req(0, work=3, key="shared")
    sched.submit(primary)
    sched.step()  # primary active in its slot
    follower = Req(1, work=3, key="shared")
    sched.submit(follower)
    assert follower.admitted_s is not None
    assert follower.admitted_s >= primary.admitted_s


def test_priority_and_deadline_admission_order():
    """Queued contention resolves highest priority first, then earliest
    deadline (EDF), then FIFO; requests with neither stay pure FIFO."""
    sched = Scheduler(DummyRunner(work=1), max_slots=1)
    blocker = Req(99, work=1)
    sched.submit(blocker)
    sched.step()  # occupy the slot so the rest queue up
    a = Req(0)                       # plain FIFO
    b = Req(1, deadline_s=60.0)      # later deadline
    c = Req(2, deadline_s=1.0)       # earliest deadline
    d = Req(3, priority=1)           # priority trumps deadlines
    for r in (a, b, c, d):
        sched.submit(r)
    sched.run_until_done()
    order = [r.rid for r in sched.finished]
    assert order == [99, 3, 2, 1, 0]


def test_plain_fifo_unchanged_without_policy_attrs():
    sched = Scheduler(DummyRunner(work=1), max_slots=1)
    for i in range(5):
        sched.submit(Req(i))
    sched.run_until_done()
    assert [r.rid for r in sched.finished] == list(range(5))


def _write_checkpoint(tmp_path):
    """A minimal train.py-shaped checkpoint dir the serving CLI can load.
    Its grid needs nx, ny >= 5 so the CLI's well-mask generator has room."""
    from repro.train import checkpoint as ckpt_lib

    cli_cfg = FNOConfig(
        grid=(8, 8, 4, 2), modes=(2, 2, 2, 1), width=2, in_channels=2,
        n_blocks=1, decoder_dim=4,
    )
    ck = str(tmp_path / "ck")
    ckpt_lib.save(
        ck, 0, {"params": init_params(jax.random.PRNGKey(0), cli_cfg)}
    )
    with open(os.path.join(ck, "fno_config.json"), "w") as f:
        json.dump({
            "grid": list(cli_cfg.grid), "modes": list(cli_cfg.modes),
            "width": cli_cfg.width, "in_channels": cli_cfg.in_channels,
            "out_channels": cli_cfg.out_channels,
            "n_blocks": cli_cfg.n_blocks,
            "decoder_dim": cli_cfg.decoder_dim, "model_shards": [1],
            "use_pallas": False, "comm_chunks": 1,
            "normalized": ["x", "y"], "normalizer": "meanstd",
            "x_stats": STATS, "y_stats": STATS,
        }, f)
    return ck


def test_all_failed_ensemble_exits_nonzero_with_admit_errors(tmp_path):
    """--rollout-steps 0 makes every admit raise: the CLI must report each
    admit error and exit nonzero — not crash indexing an empty latency
    list (the old lat[n // 2] path) or claim --max-steps is at fault."""
    ck = _write_checkpoint(tmp_path)
    env = {**os.environ, "PYTHONPATH": f"{REPO}/src"}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, f"{REPO}/src/repro/launch/serve_pde.py",
         "--ckpt-dir", ck, "--scenarios", "3", "--rollout-steps", "0",
         "--devices", "1"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode != 0
    assert proc.stderr.count("FAILED") >= 3  # one line per scenario
    assert "steps must be >= 1" in proc.stderr
    assert "3/3 scenario(s) failed" in proc.stderr
    assert "IndexError" not in proc.stderr
    assert "Traceback" not in proc.stderr
    assert "raise --max-steps" not in proc.stderr  # the old misdiagnosis


# -- gateway properties ------------------------------------------------------

def _serve_plain(runner, requests):
    sched = Scheduler(runner, BUCKET)
    for r in requests:
        sched.submit(r)
    sched.run_until_done()
    assert not sched.failed
    return requests


def test_single_replica_gateway_bitwise_identical_to_scheduler():
    runner = _make_runner()
    ref = _serve_plain(runner, [_scenario(i, steps=2) for i in range(6)])
    got = [_scenario(i, steps=2) for i in range(6)]
    gw = Gateway([runner])
    for r in got:
        gw.submit(r)
    gw.run_until_done()
    assert not gw.failed
    for a, b in zip(ref, got):
        assert len(a.outputs) == len(b.outputs) == 2
        for ya, yb in zip(a.outputs, b.outputs):
            assert np.array_equal(ya, yb)  # BIT-identical


def test_two_replica_fleet_bitwise_identical_to_single():
    """Same checkpoint on every replica + one fixed bucket shape: which
    replica served a scenario is invisible in its bits."""
    ref = _serve_plain(_make_runner(), [_scenario(i) for i in range(8)])
    got = [_scenario(i) for i in range(8)]
    gw = Gateway([_make_runner(), _make_runner()], policy="round-robin")
    for r in got:
        gw.submit(r)
    gw.run_until_done()
    assert not gw.failed
    assert all(h.routed == 4 for h in gw.replicas)
    for a, b in zip(ref, got):
        assert np.array_equal(a.prediction, b.prediction)


def test_failed_replica_fails_over_without_wedging():
    """Replica 0 breaks mid-flight: its queued+active requests move to
    replica 1 and everything still finishes."""
    gw = Gateway(
        [DummyRunner(work=2, break_after=1), DummyRunner(work=2)],
        policy="round-robin", max_slots=2,
    )
    reqs = [Req(i, work=2) for i in range(6)]
    for r in reqs:
        gw.submit(r)
    gw.run_until_done()
    assert all(r.done and r.error is None for r in reqs)
    assert not gw.failed
    assert not gw.replicas[0].healthy and gw.replicas[1].healthy
    assert gw.rerouted > 0
    stats = gw.stats()["fleet"]
    assert stats["n_healthy"] == 1 and stats["finished"] == 6


def test_no_healthy_replica_marks_orphans_failed():
    gw = Gateway([DummyRunner(work=2, break_after=1)], max_slots=2)
    reqs = [Req(i, work=2) for i in range(4)]
    for r in reqs:
        gw.submit(r)
    gw.run_until_done()
    assert len(gw.failed) == 4
    assert all(r.error is not None for r in reqs)
    with pytest.raises(RuntimeError, match="no healthy replicas"):
        gw.submit(Req(9))


def test_affinity_routing_preserves_cache_hit_rate():
    """Two geomodels, two replicas: affinity keeps each geomodel's requests
    on one replica, so the FLEET hit-rate equals the single-process rate;
    least-pending scatter splits a geomodel across replicas and pays the
    extra cold miss."""
    n = 12
    mk = lambda: [_scenario(i, geo_seed=i % 2) for i in range(n)]

    single = _make_runner(n_static=1)
    _serve_plain(single, mk())
    single_rate = single.cache.stats["hit_rate"]

    gw = Gateway([_make_runner(n_static=1), _make_runner(n_static=1)],
                 policy="affinity")
    for r in mk():
        gw.submit(r)
    gw.run_until_done()
    fleet = gw.stats()["fleet"]
    assert fleet["cache_hit_rate"] == pytest.approx(single_rate, abs=0.05)
    # the two geomodel keys were pinned to DIFFERENT replicas
    assert all(h.routed == n // 2 for h in gw.replicas)

    gw2 = Gateway([_make_runner(n_static=1), _make_runner(n_static=1)],
                  policy="least-pending")
    for r in mk():
        gw2.submit(r)
    gw2.run_until_done()
    scatter_rate = gw2.stats()["fleet"]["cache_hit_rate"]
    assert fleet["cache_hit_rate"] >= scatter_rate


def test_affinity_requests_dedup_on_one_replica():
    """Byte-identical duplicates route to the same replica under affinity,
    so in-flight dedup still absorbs them fleet-wide."""
    gw = Gateway([_make_runner(n_static=1), _make_runner(n_static=1)],
                 policy="affinity")
    base = _scenario(0, geo_seed=0)
    for rid in range(4):
        gw.submit(ScenarioRequest(rid=rid, x=base.x.copy(), steps=1))
    gw.run_until_done()
    assert gw.stats()["fleet"]["dedup_attached"] == 3


def test_autoscale_spawns_on_backlog_and_retires_idle():
    gw = Gateway(
        replica_factory=lambda: DummyRunner(work=3, max_slots=2),
        min_replicas=1, max_replicas=3,
        scale_up_backlog=4, scale_down_backlog=0, max_slots=2,
    )
    assert len(gw.replicas) == 1
    reqs = [Req(i, work=3) for i in range(16)]
    for r in reqs:
        gw.submit(r)
    gw.run_until_done()
    assert all(r.done for r in reqs)
    kinds = [k for _, k, _ in gw.scale_events]
    assert "up" in kinds and "down" in kinds
    peak = max(n for _, _, n in gw.scale_events)
    assert 1 < peak <= 3
    # retirement engaged as the backlog drained (ticks stop with the work,
    # so the fleet need not be back at min_replicas by the time we return)
    assert len(gw.replicas) < peak


def test_round_robin_and_least_pending_routing():
    gw = Gateway([DummyRunner(max_slots=2), DummyRunner(max_slots=2)],
                 policy="round-robin", max_slots=2)
    for i in range(6):
        gw.submit(Req(i))
    assert [h.routed for h in gw.replicas] == [3, 3]

    gw2 = Gateway([DummyRunner(max_slots=2), DummyRunner(max_slots=2)],
                  policy="least-pending", max_slots=2)
    gw2.submit(Req(0, work=5))
    # replica 0 now has backlog 1 -> next two go to the emptier replica 1,
    # after which replica 1 is the busier one
    gw2.submit(Req(1))
    assert gw2.replicas[1].routed == 1
    gw2.run_until_done()


def test_heterogeneous_replicas_all_finish():
    """Replicas may differ in slot count (production: different mesh
    slices); least-pending just sees backlog."""
    fast = DummyRunner(work=1, max_slots=4)
    slow = DummyRunner(work=3, max_slots=1)
    gw = Gateway([fast, slow], policy="least-pending")
    reqs = [Req(i) for i in range(10)]
    for r in reqs:
        gw.submit(r)
    gw.run_until_done()
    assert all(r.done and r.error is None for r in reqs)
    assert sum(h.routed for h in gw.replicas) == 10


def test_duplicate_runner_instances_rejected():
    r = DummyRunner()
    with pytest.raises(ValueError, match="own runner instance"):
        Gateway([r, r])


def test_serve_open_loop_event_clock_overlaps_replicas():
    """With one executor per replica, two replicas' measured service times
    overlap on the virtual timeline (~2x); one shared executor serializes
    them (~1x). The sleep IS the service time, so the ratio is tight."""
    sleep_s, n = 0.004, 8
    arrivals = [0.0] * n

    def run(n_replicas, per_replica):
        runners = [
            DummyRunner(work=1, sleep_s=sleep_s, max_slots=1)
            for _ in range(n_replicas)
        ]
        gw = Gateway(runners, policy="least-pending")
        rep = serve_open_loop(
            gw, [Req(i) for i in range(n)], arrivals,
            per_replica_executors=per_replica,
        )
        assert rep.n_served == n
        return rep.makespan_s

    single = run(1, True)
    dual = run(2, True)
    dual_one_host = run(2, False)
    assert dual < single * 0.75  # overlapped: ideally 0.5x
    assert dual_one_host > single * 0.8  # serialized: ~1x


def test_serve_open_loop_rejects_bad_arrivals():
    gw = Gateway([DummyRunner()])
    with pytest.raises(ValueError, match="nondecreasing"):
        serve_open_loop(gw, [Req(0), Req(1)], [1.0, 0.5])
    with pytest.raises(ValueError, match="arrival times"):
        serve_open_loop(gw, [Req(0)], [0.0, 1.0])


def test_drain_unfinished_empties_scheduler():
    sched = Scheduler(KeyedDummyRunner(work=5), max_slots=1)
    active = Req(0, work=5, key="a")
    queued = Req(1, work=5, key="b")
    follower = Req(2, work=5, key="a")
    sched.submit(active)
    sched.step()
    sched.submit(queued)
    sched.submit(follower)
    orphans = sched.drain_unfinished()
    assert {r.rid for r in orphans} == {0, 1, 2}
    assert not sched.has_work() and sched.pending() == 0
    # drained requests are resubmittable elsewhere: dedup state was reset
    other = Scheduler(KeyedDummyRunner(work=1), max_slots=1)
    for r in orphans:
        other.submit(r)
    other.run_until_done()
    assert all(r.done for r in orphans)
