"""Data layer: chunked store properties + PDE simulator physics sanity."""
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.store import ArrayStore


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4, 12),
    c0=st.integers(1, 4),
    a=st.integers(0, 3),
    b=st.integers(4, 8),
)
def test_store_slice_matches_numpy(n, c0, a, b):
    with tempfile.TemporaryDirectory() as d:
        data = np.random.default_rng(n).normal(size=(n, 8)).astype(np.float32)
        store = ArrayStore.create(f"{d}/x", (n, 8), "f4", (c0, 8))
        grid = store.chunk_grid()
        for i in range(grid[0]):
            lo = i * c0
            hi = min(lo + c0, n)
            store.write_chunk((i, 0), data[lo:hi])
        got = store.read_slice((slice(a, min(b, n)), slice(0, 8)))
        np.testing.assert_array_equal(got, data[a : min(b, n)])


def test_store_compression_and_dtype():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", (2, 16), "f2", (1, 16))
        x = np.linspace(0, 1, 16, dtype=np.float16)
        store.write_chunk((0, 0), x[None])
        got = store.read_chunk((0, 0))
        assert got.dtype == np.float16
        np.testing.assert_array_equal(got[0], x)


# ---------------------------------------------------------------------------
# Navier-Stokes
# ---------------------------------------------------------------------------

def test_ns_simulation_physics():
    from repro.data.pde.navier_stokes import NSConfig, simulate, sphere_mask
    import jax

    cfg = NSConfig(n=16, nt_frames=4, steps_per_frame=5)
    center = jnp.asarray([0.4, 0.5, 0.5])
    chi, vort = jax.jit(lambda c: simulate(c, cfg))(center)
    assert chi.shape == (16, 16, 16)
    assert vort.shape == (16, 16, 16, 4)
    assert bool(jnp.all(jnp.isfinite(vort)))
    # a wake forms: vorticity is strongest near the sphere, nonzero overall
    assert float(vort[..., -1].max()) > 0.1
    # sphere mask is where we asked for it
    mask = np.asarray(sphere_mask(cfg, center))
    assert mask.sum() > 0
    com = np.array(np.nonzero(mask)).mean(axis=1) / 16
    np.testing.assert_allclose(com, np.asarray(center), atol=0.1)


def test_ns_divergence_free():
    """Velocity field from the spectral solver must stay solenoidal."""
    import jax
    from repro.data.pde import navier_stokes as ns

    cfg = ns.NSConfig(n=16, nt_frames=1, steps_per_frame=5)
    kx, ky, kz, k2 = ns._wavenumbers(cfg.n)
    chi = ns.sphere_mask(cfg, jnp.asarray([0.5, 0.5, 0.5]))
    u0 = jnp.zeros((3, 16, 16, 16)).at[0].set(1.0)
    uh = jnp.fft.fftn(u0, axes=(1, 2, 3))
    uh = ns._project(uh, kx, ky, kz, k2)
    for _ in range(3):
        r = ns._rhs(uh, chi, cfg, kx, ky, kz, k2)
        uh = ns._project(uh + cfg.dt * r, kx, ky, kz, k2)
    div = kx * uh[0] + ky * uh[1] + kz * uh[2]
    assert float(jnp.abs(div).max()) < 1e-3 * float(jnp.abs(uh).max())


# ---------------------------------------------------------------------------
# Two-phase CO2
# ---------------------------------------------------------------------------

def test_co2_simulation_physics():
    from repro.data.pde.two_phase import simulate_task

    mask, sat = simulate_task(seed=1, n_wells=2, grid=(16, 8, 8), nt=6)
    assert sat.shape == (16, 8, 8, 6)
    assert np.isfinite(sat).all()
    assert (sat >= 0).all() and (sat <= 0.95).all()
    totals = [sat[..., t].sum() for t in range(6)]
    # injection: plume mass grows monotonically
    assert all(b >= a - 1e-3 for a, b in zip(totals, totals[1:]))
    assert totals[-1] > totals[0]
    # plume spreads beyond the well cells
    assert (sat[..., -1] > 0.05).sum() > mask.sum()


def test_co2_buoyancy():
    """CO2 migrates upward (toward z=0) relative to injection depth."""
    from repro.data.pde.two_phase import TwoPhaseConfig, random_well_mask, simulate
    import jax

    cfg = TwoPhaseConfig(grid=(12, 6, 10), nt_frames=8)
    mask = np.zeros(cfg.grid, np.float32)
    mask[6, 3, 7] = 1.0  # single deep injector
    sat = np.asarray(jax.jit(lambda m: simulate(m, cfg))(jnp.asarray(mask)))
    z_first = (sat[..., 1] * np.arange(10)[None, None, :]).sum() / max(sat[..., 1].sum(), 1e-9)
    z_last = (sat[..., -1] * np.arange(10)[None, None, :]).sum() / max(sat[..., -1].sum(), 1e-9)
    assert z_last < z_first + 1e-6  # center of mass rises (z index falls)
