"""End-to-end system behaviour: the paper's full pipeline at test scale.

simulate data through the cloud batch layer -> store chunked -> train the
FNO surrogate (with a mid-run injected failure + restore) -> the surrogate
beats the trivial predictor on held-out wells.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cloud import BatchPool, ThreadBackend
from repro.core import FNOConfig, fno_forward, init_params, mse_loss
from repro.data.pde.two_phase import simulate_task
from repro.data.store import ArrayStore
from repro.train import AdamWConfig, init_opt_state, make_train_step
from repro.train.fault import FaultInjector, run_supervised


@pytest.mark.timeout(900)
def test_end_to_end_pipeline():
    grid = (8, 8, 4)
    nt = 4
    n_train, n_test = 6, 2

    # -- 1. parallel data generation through the batch API ------------------
    with tempfile.TemporaryDirectory() as tmp:
        pool = BatchPool(ThreadBackend(3), store_root=f"{tmp}/blobs", n_vms=3)
        results = pool.map(
            simulate_task, [(s, 1, grid, nt) for s in range(n_train + n_test)]
        )
        rep = pool.cost_report()
        assert rep["tasks"] == n_train + n_test
        pool.shutdown()

        # -- 2. chunked store write/read (each task writes its own chunk) ---
        store = ArrayStore.create(
            f"{tmp}/y", (n_train + n_test,) + grid + (nt,), "f4", (1,) + grid + (nt,)
        )
        for i, (_, sat) in enumerate(results):
            store.write_chunk((i, 0, 0, 0, 0), sat[None])
        assert store.n_complete() == n_train + n_test

    masks = np.stack([m for m, _ in results])
    sats = np.stack([s for _, s in results])
    x = np.repeat(masks[:, None, :, :, :, None], nt, axis=-1).astype(np.float32)
    y = sats[:, None].astype(np.float32)

    # -- 3. train with a fault injected mid-run -----------------------------
    cfg = FNOConfig(grid=grid + (nt,), modes=(2, 2, 1, 2), width=8, n_blocks=2, decoder_dim=16)
    opt_cfg = AdamWConfig(lr=3e-3)
    jit_step = jax.jit(make_train_step(
        lambda p, b: (mse_loss(fno_forward(p, b["x"], cfg), b["y"]), {}), opt_cfg
    ))

    def init_state():
        p = init_params(jax.random.PRNGKey(0), cfg)
        return {"params": p, "opt": init_opt_state(p)}

    def train_step(state, batch):
        p, o, m = jit_step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    def batches(step):
        i = step % (n_train - 1)
        return {"x": jnp.asarray(x[i : i + 2]), "y": jnp.asarray(y[i : i + 2])}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        res = run_supervised(
            init_state=init_state,
            train_step=train_step,
            batch_iter=batches,
            total_steps=60,
            ckpt_dir=ckpt_dir,
            save_every=10,
            injector=FaultInjector([25]),
        )
        assert res.failures == 1 and res.restores == 1
        losses = [m["loss"] for _, m in res.metrics_log]
        assert losses[-1] < losses[0], "training did not reduce loss"

        from repro.train import checkpoint as ck
        state, _, _ = ck.restore(ckpt_dir, jax.eval_shape(init_state))

    # -- 4. surrogate beats the mean predictor on held-out wells ------------
    pred = jax.jit(lambda p, xx: fno_forward(p, xx, cfg))(
        state["params"], jnp.asarray(x[n_train:])
    )
    test_mse = float(jnp.mean((pred - y[n_train:]) ** 2))
    baseline_mse = float(np.mean((y[n_train:] - y[:n_train].mean()) ** 2))
    assert test_mse < baseline_mse, (test_mse, baseline_mse)


def test_cost_model_paper_claims():
    """Paper §V-B: FNO ~3200x cheaper per simulation than the reference
    simulator; our cost model reproduces the arithmetic."""
    from repro.cloud.api import VM_PRICES

    # OPM: 6.8h on an E8s ($0.50/h) -> $3.40/sim (paper: $3.4)
    opm_cost = 6.8 * VM_PRICES["E8s_v3"]
    np.testing.assert_allclose(opm_cost, 3.4, rtol=0.01)
    # FNO: 0.12 s on ND96amsr ($32.77/h) -> $0.0011/sim (paper: 0.11 cents)
    fno_cost = 0.12 / 3600 * VM_PRICES["ND96amsr"]
    np.testing.assert_allclose(fno_cost, 0.0011, rtol=0.05)
    ratio = opm_cost / fno_cost
    assert 2800 < ratio < 3600  # paper: "a factor of 3,200"


def test_production_mesh_shapes():
    """make_production_mesh contract (shape/axes) without touching devices."""
    from repro.common.constants import (
        MULTIPOD_MESH_AXES, MULTIPOD_MESH_SHAPE, POD_MESH_AXES, POD_MESH_SHAPE,
    )

    assert POD_MESH_SHAPE == (16, 16) and POD_MESH_AXES == ("data", "model")
    assert MULTIPOD_MESH_SHAPE == (2, 16, 16)
    assert MULTIPOD_MESH_AXES == ("pod", "data", "model")
    assert int(np.prod(MULTIPOD_MESH_SHAPE)) == 512
