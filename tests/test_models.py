"""Per-architecture smoke tests + model-level properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.tree import tree_params
from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import (
    init_cache, init_lm_params, init_whisper_params, lm_decode_step, lm_loss,
    lm_prefill, whisper_decode_step, whisper_loss, whisper_prefill,
)
from repro.models.policy import LOCAL

B, S = 2, 32


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    """Reduced same-family config: one train step's loss fwd + serve round."""
    cfg = reduced(get_arch(arch_id))
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    if cfg.family == "encdec":
        params = init_whisper_params(key, cfg)
        frames = jax.random.normal(key, (B, cfg.encoder.frames, cfg.d_model))
        batch = {"tokens": tokens, "targets": targets, "frames": frames}
        loss, metrics = jax.jit(lambda p, b: whisper_loss(p, b, cfg, LOCAL))(params, batch)
        logits, cache = jax.jit(
            lambda p, t, f: whisper_prefill(p, t, f, cfg, LOCAL, max_len=S + 4)
        )(params, tokens, frames)
        logits2, _ = jax.jit(
            lambda p, t, c, i: whisper_decode_step(p, t, c, i, cfg, LOCAL)
        )(params, tokens[:, :1], cache, jnp.asarray(S, jnp.int32))
    else:
        params = init_lm_params(key, cfg)
        batch = {"tokens": tokens, "targets": targets}
        loss, metrics = jax.jit(lambda p, b: lm_loss(p, b, cfg, LOCAL))(params, batch)
        logits, cache = jax.jit(
            lambda p, t: lm_prefill(p, t, cfg, LOCAL, max_len=S + 4)
        )(params, tokens)
        logits2, _ = jax.jit(
            lambda p, t, c, i: lm_decode_step(p, t, c, i, cfg, LOCAL)
        )(params, tokens[:, :1], cache, jnp.asarray(S, jnp.int32))
    assert jnp.isfinite(loss), arch_id
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))) and bool(jnp.all(jnp.isfinite(logits2)))
    assert tree_params(params) > 0
    # loss should be near ln(vocab) at init (uniform predictions)
    assert abs(float(metrics["xent"]) - np.log(cfg.vocab)) < 1.5, arch_id


@pytest.mark.parametrize("arch_id", ["qwen1.5-32b", "deepseek-v2-lite-16b", "mamba2-370m", "recurrentgemma-2b"])
def test_decode_matches_prefill(arch_id):
    """prefill(S) last logits == prefill(S-1) + one decode step."""
    cfg = reduced(get_arch(arch_id))
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = jax.jit(lambda p, t: lm_prefill(p, t, cfg, LOCAL))(params, tokens)
    pre, cache = jax.jit(lambda p, t: lm_prefill(p, t, cfg, LOCAL, max_len=S))(
        params, tokens[:, : S - 1]
    )
    step, _ = jax.jit(lambda p, t, c, i: lm_decode_step(p, t, c, i, cfg, LOCAL))(
        params, tokens[:, S - 1 : S], cache, jnp.asarray(S - 1, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=5e-2, atol=5e-2)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence (independent oracle)."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n = 2, 32, 3, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y = ssd_chunked(x, dt, a_log, bm, cm, chunk=8)

    a = -np.exp(np.asarray(a_log))
    xn, dtn, bn, cn = map(np.asarray, (x, dt, bm, cm))
    state = np.zeros((b, h, n, p))
    y_ref = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dtn[:, t] * a)  # [b,h]
        inp = np.einsum("bn,bhp->bhnp", bn[:, t], xn[:, t] * dtn[:, t][..., None])
        state = state * decay[:, :, None, None] + inp
        y_ref[:, t] = np.einsum("bn,bhnp->bhp", cn[:, t], state)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-4)


def test_rglru_scan_matches_loop():
    from repro.models.rglru import _rglru_scan

    b, s, w = 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, w))
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, w)))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, w)))
    lam = jax.random.normal(ks[3], (w,))
    h = np.asarray(_rglru_scan(x, r, i, lam))

    import math
    log_a = -8.0 * np.log1p(np.exp(np.asarray(lam))) * np.asarray(r)
    a = np.exp(log_a)
    gated = np.sqrt(np.maximum(1 - np.exp(2 * log_a), 1e-12)) * (np.asarray(i) * np.asarray(x))
    href = np.zeros((b, w))
    out = np.zeros((b, s, w))
    for t in range(s):
        href = a[:, t] * href + gated[:, t]
        out[:, t] = href
    np.testing.assert_allclose(h, out, rtol=2e-4, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(t=st.integers(4, 40), e=st.sampled_from([4, 8]), k=st.integers(1, 3))
def test_moe_dispatch_combine_conservation(t, e, k):
    """With ample capacity, combine(dispatch(x)) with identity experts
    reproduces sum_k w_k * x (router mixture of the token itself)."""
    from repro.models.moe import MoEConfig, _capacity, _combine, _dispatch, _route

    d = 16
    moe = MoEConfig(n_experts=e, top_k=k, d_expert=8, capacity_factor=float(e))
    x = jax.random.normal(jax.random.PRNGKey(t), (t, d))
    router = jax.random.normal(jax.random.PRNGKey(1), (d, e)) * 0.1
    topi, topv, probs = _route(x, router, moe)
    cap = _capacity(t, moe)
    buf, e_flat, pos, keep = _dispatch(x, topi, topv, cap, e)
    assert bool(jnp.all(keep)), "ample capacity should drop nothing"
    y = _combine(buf, e_flat, pos, keep, topv, t, cap)  # identity "experts"
    expected = jnp.sum(topv, axis=-1, keepdims=True) * x
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected), rtol=2e-3, atol=2e-4)


def test_windowed_attention_matches_masked_ref():
    from repro.models.attention import _windowed_attention
    from repro.kernels.flash_attention import attention_ref

    b, h, s, d, w = 1, 2, 64, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    out = _windowed_attention(q, k, v, w)
    # reference: dense with band mask (kpos in (qpos-w, qpos])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (d ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    logits = jnp.where(mask, logits, -jnp.inf)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_chunked_xent_matches_dense():
    from repro.models.layers import chunked_cross_entropy

    b, s, d, v = 2, 16, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v))
    t = jax.random.randint(ks[2], (b, s), 0, v)
    got = chunked_cross_entropy(h, w, t, chunk=4)
    logits = h @ w
    dense = jnp.mean(
        jax.nn.logsumexp(logits, -1) - jnp.take_along_axis(logits, t[..., None], -1)[..., 0]
    )
    np.testing.assert_allclose(float(got), float(dense), rtol=1e-5)


def test_quantized_split_cache_close_to_bf16():
    """int8 prefix cache decode ~= bf16 split-cache decode (small rel err)."""
    from repro.models import attention as attn_lib

    cfg = reduced(get_arch("qwen1.5-32b"))
    p = attn_lib.init_attn_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    h = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
    hist = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))
    _, k, v = attn_lib._project_qkv(p, hist, cfg, jnp.arange(s))
    kt, vt = k.swapaxes(1, 2), v.swapaxes(1, 2)
    tail = jnp.zeros((b, cfg.kv_heads, attn_lib.TAIL_LEN, cfg.head_dim_))
    split = {"k": kt, "v": vt, "tk": tail, "tv": tail}
    kq, ks = attn_lib.quantize_kv(kt)
    vq, vs = attn_lib.quantize_kv(vt)
    quant = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs, "tk": tail, "tv": tail}
    idx = jnp.asarray(s, jnp.int32)
    out_bf16, _ = attn_lib.attn_decode(p, h, split, idx, cfg)
    out_int8, _ = attn_lib.attn_decode(p, h, quant, idx, cfg)
    err = float(jnp.max(jnp.abs(out_int8 - out_bf16)))
    ref = float(jnp.max(jnp.abs(out_bf16)))
    assert err < 0.05 * ref, (err, ref)


def test_split_cache_decode_matches_plain():
    """Prefix/tail split cache decode == plain cache decode (local math)."""
    from repro.models import attention as attn_lib

    cfg = reduced(get_arch("qwen1.5-32b"))
    p = attn_lib.init_attn_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    h = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
    # build both caches from the same history
    hist = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))
    positions = jnp.arange(s)
    _, k, v = attn_lib._project_qkv(p, hist, cfg, positions)
    kt, vt = k.swapaxes(1, 2), v.swapaxes(1, 2)
    plain = {
        "k": jnp.pad(kt, ((0, 0), (0, 0), (0, 4), (0, 0))),
        "v": jnp.pad(vt, ((0, 0), (0, 0), (0, 4), (0, 0))),
    }
    split = {
        "k": kt, "v": vt,
        "tk": jnp.zeros((b, cfg.kv_heads, attn_lib.TAIL_LEN, cfg.head_dim_)),
        "tv": jnp.zeros((b, cfg.kv_heads, attn_lib.TAIL_LEN, cfg.head_dim_)),
    }
    idx = jnp.asarray(s, jnp.int32)
    out_plain, _ = attn_lib.attn_decode(p, h, plain, idx, cfg)
    out_split, _ = attn_lib.attn_decode(p, h, split, idx, cfg)
    np.testing.assert_allclose(
        np.asarray(out_split), np.asarray(out_plain), rtol=2e-3, atol=2e-4
    )
