"""Truncated-FFT operator properties (the paper's S and F operators)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import dfft


def _rand_complex(key, shape):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, shape) + 1j * jax.random.normal(k2, shape)).astype(jnp.complex64)


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([8, 12, 16]), m=st.integers(1, 4), axis=st.integers(2, 5))
def test_truncate_pad_adjoint(n, m, axis):
    """<S x, y> == <x, S^T y> — S (truncation) and S^T (zero-pad) are adjoints."""
    if 2 * m > n:
        m = n // 2
    shape = [2, 3, n, n, n, n]
    key = jax.random.PRNGKey(n * 10 + m)
    x = _rand_complex(key, tuple(shape))
    tshape = list(shape)
    tshape[axis] = 2 * m
    y = _rand_complex(jax.random.PRNGKey(7), tuple(tshape))
    sx = dfft.truncate_full(x, axis, m)
    sty = dfft.pad_full(y, axis, n)
    lhs = jnp.vdot(sx, y)
    rhs = jnp.vdot(x, sty)
    np.testing.assert_allclose(complex(lhs), complex(rhs), rtol=1e-5, atol=1e-5)


def test_rfft_truncate_pad_adjoint():
    key = jax.random.PRNGKey(0)
    x = _rand_complex(key, (2, 3, 4, 4, 4, 9))
    y = _rand_complex(jax.random.PRNGKey(1), (2, 3, 4, 4, 4, 5))
    lhs = jnp.vdot(dfft.truncate_rfft(x, 5, 5), y)
    rhs = jnp.vdot(x, dfft.pad_rfft(y, 5, 9))
    np.testing.assert_allclose(complex(lhs), complex(rhs), rtol=1e-5)


def test_serial_roundtrip_bandlimited():
    """Band-limiting behaviour of the FNO corner-mode set.

    The classic [:m]+[-m:] corner set is NOT Hermitian-symmetric (index m
    pairs with n-m, which is kept, while m itself is not), so A∘F is a
    CONTRACTION rather than a projection on real fields: the unpaired
    modes halve every pass. We assert (a) the contraction, and (b) exact
    idempotence once the unpaired slice (local index m of each full dim)
    is zeroed — the truly symmetric sub-space."""
    cfg_grid = (16, 16, 8, 8)
    modes = (4, 4, 2, 3)
    x0 = jax.random.normal(jax.random.PRNGKey(2), (1, 2) + cfg_grid)

    def af(x):
        return dfft.serial_adjoint(dfft.serial_forward(x, modes), cfg_grid)

    x1, x2, x3 = af(x0), af(af(x0)), af(af(af(x0)))
    d1 = float(jnp.max(jnp.abs(x2 - x1)))
    d2 = float(jnp.max(jnp.abs(x3 - x2)))
    assert d2 < 0.6 * d1  # geometric contraction of the unpaired modes

    # symmetric sub-space: zero the unpaired mode slice per full-fft dim
    spec = dfft.serial_forward(x0, modes)
    mx, my, mz, _ = modes
    spec = spec.at[:, :, mx].set(0).at[:, :, :, my].set(0).at[:, :, :, :, mz].set(0)
    xs = dfft.serial_adjoint(spec, cfg_grid)
    xs2 = af(xs)
    np.testing.assert_allclose(np.asarray(xs2), np.asarray(xs), rtol=1e-4, atol=1e-5)


def test_forward_matches_numpy_oracle():
    """serial_forward == rfftn + explicit corner selection (independent impl)."""
    x = np.random.default_rng(0).normal(size=(1, 1, 8, 8, 8, 8)).astype(np.float32)
    modes = (2, 3, 2, 3)
    got = np.asarray(dfft.serial_forward(jnp.asarray(x), modes))
    full = np.fft.rfftn(x, axes=(2, 3, 4, 5))
    mx, my, mz, mt = modes
    sel = full[:, :, np.r_[0:mx, 8 - mx : 8], :, :, :]
    sel = sel[:, :, :, np.r_[0:my, 8 - my : 8], :, :]
    sel = sel[:, :, :, :, np.r_[0:mz, 8 - mz : 8], :]
    sel = sel[:, :, :, :, :, :mt]
    np.testing.assert_allclose(got, sel, rtol=1e-4, atol=1e-4)
