"""Multi-device (8 simulated hosts) equivalence tests, via subprocess —
the device-count flag must be set before jax initializes, so the checks
cannot import jax in the main pytest process (whose device count is
environment-dependent: 1 locally, 8 under the CI flag)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(1200)
def test_distributed_checks():
    script = os.path.join(os.path.dirname(__file__), "distributed_checks.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True,
        text=True,
        timeout=1200,
        env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed checks failed (see output)"
    assert "ALL_DISTRIBUTED_CHECKS_PASSED" in proc.stdout
