"""Geomodel content-hash cache + serving request-lifecycle regressions.

Covers this PR's contract:
  * property: WARM-cache ensemble serving is BITWISE-identical to the
    cold-cache path under mixed admission order, slot reuse, shared/unique
    geomodels, and multi-step rollouts (the cache only changes whether the
    deterministic host prelift is recomputed, never its value);
  * the property holds at BOTH cache levels: ``prelift`` (encoder-only)
    and ``deep`` (the block-input split serving cached first-block
    kept-mode spectra/contribution through ``fno_forward_deep_split``);
  * the split forward (cached static prelift + dynamic lift) matches the
    fused ``fno_forward`` to float tolerance, and so does the deep split
    (``spectral_prelift`` + ``fno_forward_deep_split``);
  * scheduler dedup: identical in-flight requests ride one slot and every
    follower gets the primary's outputs at retirement;
  * LRU eviction honors the byte budget, strips the DEEP levels of the
    LRU entry before fully evicting it, and eviction never invalidates
    (or mutates) an entry a caller still holds — including a deep strip
    landing mid-rollout while a slot holds its reference;
  * lifecycle regressions: a raising ``admit`` marks the request failed
    without wedging the pool; the bucket ladder must cover ``max_slots``
    at construction; ``run_until_done`` warns on exhausted ``max_steps``
    and ``prediction`` raises a clear error on unserved requests.
"""
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FNOConfig, encoder_prelift, fno_forward, fno_forward_deep_split,
    init_params, spectral_prelift,
)
from repro.core.partition import make_mesh
from repro.data.loader import Normalizer
from repro.serve import (
    FNORunner, GeomodelCache, GeomodelEntry, ScenarioRequest, Scheduler,
    content_key,
)

# Tiny FNO with 2 static (geomodel) + 1 dynamic channel; module-level so
# the jit cache persists across hypothesis examples.
N_STATIC = 2
CFG = FNOConfig(
    grid=(8, 4, 4, 2), modes=(2, 2, 2, 1), width=2, n_blocks=2,
    decoder_dim=4, in_channels=N_STATIC + 1,
)
PARAMS = init_params(jax.random.PRNGKey(3), CFG)
BUCKET = 4
X_STATS = {"mean": [0.2, -0.4, 0.1], "std": [0.7, 1.3, 0.8]}
Y_STATS = {"mean": [0.1], "std": [0.8]}


def _make_runner(**kw):
    kw.setdefault("max_slots", BUCKET)
    kw.setdefault("buckets", (BUCKET,))
    return FNORunner(
        CFG,
        PARAMS,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        x_normalizer=Normalizer.from_stats(X_STATS, "meanstd"),
        y_normalizer=Normalizer.from_stats(Y_STATS, "meanstd"),
        n_static=N_STATIC,
        **kw,
    )


RUNNER = _make_runner(cache=GeomodelCache())  # default level: "deep"
RUNNER_PRELIFT = _make_runner(cache=GeomodelCache(), cache_level="prelift")
RUNNERS = {"deep": RUNNER, "prelift": RUNNER_PRELIFT}

# a small pool of geomodels so hypothesis examples exercise SHARING
GEOMODELS = [
    np.random.default_rng(100 + g)
    .normal(size=(N_STATIC,) + CFG.grid)
    .astype(np.float32)
    for g in range(3)
]


def _scenario(rid: int, geo: int, steps: int = 1) -> ScenarioRequest:
    rng = np.random.default_rng(1000 + rid)
    dyn = rng.normal(size=(1,) + CFG.grid).astype(np.float32)
    x = np.concatenate([GEOMODELS[geo], dyn], axis=0)
    return ScenarioRequest(rid=rid, x=x, steps=steps)


def _serve(runner, requests, max_slots, interleave=0, split=None):
    sched = Scheduler(runner, max_slots)
    split = len(requests) if split is None else min(split, len(requests))
    for r in requests[:split]:
        sched.submit(r)
    for _ in range(interleave):
        sched.step()
    for r in requests[split:]:
        sched.submit(r)
    done = sched.run_until_done(max_steps=500)
    assert len(done) == len(requests)
    return done, sched


# ---------------------------------------------------------------------------
# Tentpole property: warm cache is bitwise-invisible in the outputs.
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    geos=st.lists(st.integers(0, 2), min_size=1, max_size=7),
    max_slots=st.integers(1, BUCKET),
    split=st.integers(0, 7),
    steps=st.integers(1, 3),
    interleave=st.integers(0, 3),
    level=st.sampled_from(("deep", "prelift")),
)
def test_warm_cache_bitwise_identical_to_cold(
    geos, max_slots, split, steps, interleave, level
):
    """Cold (cache disabled) and warm (shared cache) serving of the same
    mixed-geomodel ensemble produce bit-identical outputs per request —
    at both cache levels (encoder prelift only, and the deep block-input
    split serving cached kept-mode contributions)."""
    runner = RUNNERS[level]
    runner.cache = None
    cold, _ = _serve(
        runner, [_scenario(i, g, steps) for i, g in enumerate(geos)],
        max_slots, interleave, split,
    )
    runner.cache = GeomodelCache()
    warm, _ = _serve(
        runner, [_scenario(i, g, steps) for i, g in enumerate(geos)],
        max_slots, interleave, split,
    )
    assert runner.cache.stats["misses"] == len(set(geos))
    lb = runner.cache.stats["level_bytes"]
    if level == "deep":
        assert lb["spectra"] > 0 and lb["contribution"] > 0
    else:
        assert lb["spectra"] == lb["contribution"] == 0
    for rc, rw in zip(
        sorted(cold, key=lambda r: r.rid), sorted(warm, key=lambda r: r.rid)
    ):
        assert rc.rid == rw.rid and len(rc.outputs) == len(rw.outputs) == steps
        for yc, yw in zip(rc.outputs, rw.outputs):
            np.testing.assert_array_equal(yc, yw)


def test_cache_hit_rate_counts_requests_and_rollout_steps():
    """One shared geomodel, N scenarios x S steps: lookups happen per slot
    per tick, so exactly one miss and N*S - 1 hits."""
    RUNNER.cache = GeomodelCache()
    n, steps = 6, 2
    _serve(RUNNER, [_scenario(i, 0, steps) for i in range(n)], BUCKET)
    s = RUNNER.cache.stats
    assert (s["misses"], s["hits"]) == (1, n * steps - 1)
    assert s["hit_rate"] == pytest.approx(1 - 1 / (n * steps))


def test_split_forward_matches_fused_to_tolerance():
    """The split (prelift + dynamic lift) path equals the fused single-
    encoder forward up to float summation order."""
    fwd = jax.jit(lambda p, x: fno_forward(p, x, CFG))
    for i in range(4):
        req = _scenario(i, i % 3)
        done, _ = _serve(RUNNER, [req], 1)
        xe = RUNNER.x_normalizer.encode(np.asarray(req.x, np.float32)[None])
        expected = RUNNER.y_normalizer.decode(np.asarray(fwd(PARAMS, xe)))[0]
        np.testing.assert_allclose(req.prediction, expected, rtol=1e-4, atol=1e-5)


def test_deep_split_forward_matches_fused_to_tolerance():
    """The block-input split — cached first-block static kept-mode
    contribution (``spectral_prelift``) summed into the dynamic remainder's
    pre-activation (``fno_forward_deep_split``) — equals the fused forward
    up to float summation order."""
    rng = np.random.default_rng(11)
    x = rng.normal(size=(2, CFG.in_channels) + CFG.grid).astype(np.float32)
    pre_s = encoder_prelift(PARAMS, x[:, :N_STATIC], CFG, slice(0, N_STATIC))
    spectra, contrib = spectral_prelift(PARAMS, pre_s, CFG)
    assert spectra.shape == (2, CFG.width) + CFG.mode_shape
    assert contrib.shape == (2, CFG.width) + CFG.mode_shape
    got = fno_forward_deep_split(
        PARAMS, contrib, pre_s, x[:, N_STATIC:], CFG, N_STATIC
    )
    want = fno_forward(PARAMS, x, CFG)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )
    # unbatched spectral_prelift matches the batched slice
    s0, c0 = spectral_prelift(PARAMS, pre_s[0], CFG)
    np.testing.assert_allclose(
        np.asarray(c0), np.asarray(contrib[0]), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(s0), np.asarray(spectra[0]), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Scheduler dedup: identical in-flight requests ride one slot.
# ---------------------------------------------------------------------------

def test_dedup_fans_out_primary_outputs_to_followers():
    base = _scenario(0, 0, steps=2)
    dups = [
        ScenarioRequest(rid=i, x=base.x.copy(), steps=2) for i in (1, 2)
    ]
    other = _scenario(3, 1, steps=2)
    done, sched = _serve(RUNNER, [base, *dups, other], 2)
    assert sched.dedup_attached == 2
    # followers never occupied a slot: 3-deep identical work took the
    # engine steps of 2 distinct requests in 2 slots
    assert sched.steps == 2
    for d in dups:
        assert d.done and len(d.outputs) == 2
        for got, exp in zip(d.outputs, base.outputs):
            np.testing.assert_array_equal(got, exp)
    assert not np.array_equal(other.prediction, base.prediction)


def test_dedup_respects_rollout_length_and_opt_out():
    """Same content but different steps is NOT identical work; dedup=False
    disables attaching entirely."""
    base = _scenario(0, 0, steps=1)
    longer = ScenarioRequest(rid=1, x=base.x.copy(), steps=2)
    done, sched = _serve(RUNNER, [base, longer], 2)
    assert sched.dedup_attached == 0
    assert len(base.outputs) == 1 and len(longer.outputs) == 2

    twin = ScenarioRequest(rid=2, x=base.x.copy(), steps=1)
    sched = Scheduler(RUNNER, 2, dedup=False)
    sched.submit(base)
    sched.submit(twin)
    assert sched.run_until_done(max_steps=50) and sched.dedup_attached == 0


# ---------------------------------------------------------------------------
# LRU eviction under the byte budget.
# ---------------------------------------------------------------------------

def _entry(seed: int) -> GeomodelEntry:
    arr = np.random.default_rng(seed).normal(size=(4, 4)).astype(np.float32)
    return GeomodelEntry(content_key(arr), arr, arr * 2.0)


def test_eviction_respects_byte_budget_lru_first():
    e = [_entry(i) for i in range(4)]
    per = e[0].nbytes
    cache = GeomodelCache(max_bytes=2 * per)  # room for exactly two
    cache.put(e[0].key, e[0])
    cache.put(e[1].key, e[1])
    assert len(cache) == 2 and cache.bytes == 2 * per
    assert cache.get(e[0].key) is e[0]  # touch: e[1] is now LRU
    cache.put(e[2].key, e[2])
    assert cache.get(e[1].key) is None  # evicted LRU-first
    assert cache.get(e[0].key) is e[0] and cache.get(e[2].key) is e[2]
    assert cache.bytes <= cache.max_bytes and cache.evictions == 1
    # an entry larger than the whole budget: strict budget, caller keeps
    # its own reference (returned), nothing retained
    big_arr = np.zeros((64, 64), np.float32)
    big = GeomodelEntry(content_key(big_arr), big_arr, big_arr)
    assert cache.put(big.key, big) is big
    assert cache.get(big.key) is None and cache.bytes <= cache.max_bytes
    # re-putting an existing key refreshes, never double-counts
    cache.put(e[0].key, e[0])
    assert cache.bytes <= 2 * per
    with pytest.raises(ValueError, match="max_bytes"):
        GeomodelCache(max_bytes=0)


def test_eviction_never_invalidates_served_requests():
    """A budget that can hold only ONE geomodel still serves a two-geomodel
    ensemble correctly: slots keep their own entry references."""
    one = GEOMODELS[0].nbytes // N_STATIC * (N_STATIC + CFG.width) + 1
    RUNNER.cache = GeomodelCache(max_bytes=one)
    geos = [0, 1, 0, 1, 0, 1]
    done, _ = _serve(RUNNER, [_scenario(i, g, 2) for i, g in enumerate(geos)], BUCKET)
    assert RUNNER.cache.evictions > 0
    RUNNER.cache = None
    cold, _ = _serve(RUNNER, [_scenario(i, g, 2) for i, g in enumerate(geos)], BUCKET)
    for rw, rc in zip(done, cold):
        for yw, yc in zip(rw.outputs, rc.outputs):
            np.testing.assert_array_equal(yw, yc)


def _deep_entry(seed: int) -> GeomodelEntry:
    """An entry with all four levels populated (synthetic deep arrays)."""
    rng = np.random.default_rng(seed)
    arr = rng.normal(size=(4, 4)).astype(np.float32)
    spec = (
        rng.normal(size=(2, 3)) + 1j * rng.normal(size=(2, 3))
    ).astype(np.complex64)
    return GeomodelEntry(content_key(arr), arr, arr * 2.0, spec, spec * 0.5)


def test_deep_eviction_strips_lru_before_full_eviction():
    """Over budget, the LRU entry first loses only its deep levels
    (kept-mode spectra + contribution); full eviction happens only once the
    LRU is already shallow. Byte accounting follows each transition."""
    e0, e1 = _deep_entry(0), _deep_entry(1)
    full, shallow = e0.nbytes, e0.without_deep().nbytes
    cache = GeomodelCache(max_bytes=full + shallow)
    cache.put(e0.key, e0)
    cache.put(e1.key, e1)
    assert (cache.deep_evictions, cache.evictions) == (1, 0)
    assert cache.bytes == shallow + full
    got0, got1 = cache.get(e0.key), cache.get(e1.key)
    assert not got0.has_deep and got1.has_deep  # LRU lost only its depth
    np.testing.assert_array_equal(got0.normalized, e0.normalized)
    np.testing.assert_array_equal(got0.prelift, e0.prelift)
    s = cache.stats
    assert s["level_bytes"]["contribution"] == e1.contribution.nbytes
    assert s["level_bytes"]["normalized"] == 2 * e0.normalized.nbytes
    assert sum(s["level_bytes"].values()) == cache.bytes == s["bytes"]
    # third entry: the (already shallow) LRU e0 is now fully evicted, and
    # e1 — next in LRU order — gets deep-stripped to make room
    e2 = _deep_entry(2)
    cache.put(e2.key, e2)
    assert (cache.deep_evictions, cache.evictions) == (2, 1)
    assert cache.get(e0.key) is None
    assert not cache.get(e1.key).has_deep
    assert cache.get(e2.key).has_deep
    assert cache.bytes <= cache.max_bytes


def test_deep_strip_never_mutates_a_held_entry():
    """Deep eviction replaces the cache's entry with a stripped COPY: a
    serving slot holding the original keeps its spectra/contribution."""
    e0, e1 = _deep_entry(3), _deep_entry(4)
    cache = GeomodelCache(max_bytes=e0.nbytes + e0.without_deep().nbytes)
    held = cache.put(e0.key, e0)
    cache.put(e1.key, e1)  # strips the cache's copy of e0
    assert held is e0
    assert held.spectra is not None and held.contribution is not None
    assert cache.get(e0.key).spectra is None  # the cached copy IS stripped


def test_reput_after_level_growth_updates_byte_accounting():
    """Growing an entry's deep levels and re-putting it under the same key
    replaces the recorded size — no double counting."""
    e = _deep_entry(5)
    cache = GeomodelCache()
    cache.put(e.key, e.without_deep())
    assert cache.bytes == e.without_deep().nbytes
    cache.put(e.key, e)
    assert cache.bytes == e.nbytes and len(cache) == 1
    cache.clear()
    assert cache.bytes == 0 and len(cache) == 0


def test_mid_rollout_deep_eviction_is_bitwise_invisible():
    """A budget that fits one FULL entry but not two: two alternating
    geomodels keep their shallow levels cached while their kept-mode
    spectra/contribution are repeatedly deep-evicted mid-rollout (each
    slot holds its entry reference for the tick). Serving must stay
    bitwise-identical to the cold path and never fully evict."""
    probe = GeomodelCache()
    RUNNER.cache = probe
    _serve(RUNNER, [_scenario(0, 0)], 1)
    full = probe.bytes
    lb = probe.stats["level_bytes"]
    shallow = lb["normalized"] + lb["prelift"]
    assert lb["spectra"] > 0 and lb["contribution"] > 0
    geos = [0, 1, 0, 1]
    RUNNER.cache = GeomodelCache(max_bytes=full + shallow + 1)
    warm, _ = _serve(
        RUNNER, [_scenario(i, g, 3) for i, g in enumerate(geos)], 2
    )
    assert RUNNER.cache.deep_evictions > 0
    assert RUNNER.cache.evictions == 0  # shallow levels never left
    RUNNER.cache = None
    cold, _ = _serve(
        RUNNER, [_scenario(i, g, 3) for i, g in enumerate(geos)], 2
    )
    for rw, rc in zip(warm, cold):
        assert len(rw.outputs) == len(rc.outputs) == 3
        for yw, yc in zip(rw.outputs, rc.outputs):
            np.testing.assert_array_equal(yw, yc)


def test_datagen_geomodel_prepends_shared_static_channel(tmp_path):
    """``datagen --geomodel`` writes a 2-channel x store whose leading
    channel is the SAME log-permeability realization in every sample —
    the content the serving cache keys on."""
    from repro.data import ArrayStore
    from repro.launch.datagen import geomodel_channel, main as datagen

    d = str(tmp_path / "ds")
    datagen([
        "--pde", "two_phase", "--n", "2", "--grid", "8", "8", "4",
        "--nt", "2", "--out", d, "--backend", "thread", "--workers", "2",
        "--geomodel",
    ])
    xs = ArrayStore.open(f"{d}/x")
    assert xs.shape[1] == 2 and len(xs.meta["stats"]["mean"]) == 2
    full = xs.read_slice((slice(0, 2),) + (slice(None),) * 5)
    np.testing.assert_array_equal(full[0, 0], full[1, 0])  # shared geomodel
    np.testing.assert_array_equal(full[0, 0], geomodel_channel((8, 8, 4), 2)[0])
    assert full[0, 0].std() > 0  # a real field, not a constant fill


def test_content_key_discriminates():
    a = np.arange(8, dtype=np.float32)
    assert content_key(a) == content_key(a.copy())
    assert content_key(a) != content_key(a.astype(np.float64))
    assert content_key(a) != content_key(a.reshape(2, 4))
    b = a.copy()
    b[3] = np.nextafter(b[3], np.float32(np.inf))  # one-ulp flip
    assert content_key(a) != content_key(b)


def test_content_key_noncontiguous_matches_contiguous(monkeypatch):
    """Non-contiguous arrays are hashed in bounded leading-axis slabs (no
    full ``tobytes`` copy); the digest must equal the contiguous-copy
    digest — including when the slab size forces many chunks."""
    import repro.serve.geomodel_cache as gc

    rng = np.random.default_rng(0)
    base = rng.normal(size=(32, 9, 3)).astype(np.float32)
    for view in (base[::2], base.transpose(1, 0, 2), base[5:21, ::3]):
        assert not view.flags["C_CONTIGUOUS"]
        assert content_key(view) == content_key(np.ascontiguousarray(view))
    monkeypatch.setattr(gc, "_HASH_CHUNK_ROWS_BYTES", 64)  # many tiny slabs
    view = base[::2]
    assert gc.content_key(view) == content_key(np.ascontiguousarray(view))
    # degenerate shapes: 0-d and empty arrays hash stably and distinctly
    assert content_key(np.float32(3.5)) == content_key(
        np.asarray(3.5, np.float32)
    )
    assert content_key(np.zeros((0, 4), np.float32)) != content_key(
        np.zeros((4, 0), np.float32)
    )


# ---------------------------------------------------------------------------
# Lifecycle regressions.
# ---------------------------------------------------------------------------

def test_failing_admit_marks_failed_and_pool_stays_serviceable():
    bad = ScenarioRequest(rid=0, x=_scenario(0, 0).x, steps=0)  # admit raises
    wrong_shape = ScenarioRequest(
        rid=1, x=np.zeros((CFG.in_channels, 2, 2, 2, 2), np.float32)
    )
    good = [_scenario(i, 0) for i in range(2, 5)]
    sched = Scheduler(RUNNER, 2)
    for r in (bad, wrong_shape, *good):
        sched.submit(r)
    done = sched.run_until_done(max_steps=50)
    assert sorted(r.rid for r in done) == [2, 3, 4]
    assert sorted(r.rid for r in sched.failed) == [0, 1]
    for r in sched.failed:
        assert r.done and r.error is not None
        with pytest.raises(RuntimeError, match=f"request {r.rid} failed"):
            r.prediction
    assert sched.pending() == 0


def test_failing_primary_fails_its_followers():
    bad = ScenarioRequest(rid=0, x=_scenario(0, 0).x, steps=0)
    twin = ScenarioRequest(rid=1, x=bad.x.copy(), steps=0)
    sched = Scheduler(RUNNER, 2)
    sched.submit(bad)
    sched.submit(twin)
    assert sched.dedup_attached == 1
    sched.run_until_done(max_steps=50)
    assert sorted(r.rid for r in sched.failed) == [0, 1]
    assert twin.error is not None and sched.pending() == 0


def test_bucket_ladder_must_cover_max_slots_at_construction():
    with pytest.raises(ValueError, match="largest bucket"):
        _make_runner(max_slots=8, buckets=(2, 4))


def test_run_until_done_warns_on_exhausted_max_steps():
    sched = Scheduler(RUNNER, 1)
    reqs = [_scenario(i, 0, steps=3) for i in range(2)]
    for r in reqs:
        sched.submit(r)
    with pytest.warns(RuntimeWarning, match="max_steps=2 exhausted.*2 request"):
        done = sched.run_until_done(max_steps=2)
    assert len(done) < 2
    unserved = next(r for r in reqs if not r.outputs)
    with pytest.raises(RuntimeError, match="no completed rollout steps"):
        unserved.prediction
    # the drained remainder finishes on a fresh budget
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sched.steps = 0
        assert len(sched.run_until_done(max_steps=50)) == 2
