"""Geomodel content-hash cache + serving request-lifecycle regressions.

Covers this PR's contract:
  * property: WARM-cache ensemble serving is BITWISE-identical to the
    cold-cache path under mixed admission order, slot reuse, shared/unique
    geomodels, and multi-step rollouts (the cache only changes whether the
    deterministic host prelift is recomputed, never its value);
  * the split forward (cached static prelift + dynamic lift) matches the
    fused ``fno_forward`` to float tolerance;
  * scheduler dedup: identical in-flight requests ride one slot and every
    follower gets the primary's outputs at retirement;
  * LRU eviction honors the byte budget, and eviction never invalidates
    an entry a caller still holds;
  * lifecycle regressions: a raising ``admit`` marks the request failed
    without wedging the pool; the bucket ladder must cover ``max_slots``
    at construction; ``run_until_done`` warns on exhausted ``max_steps``
    and ``prediction`` raises a clear error on unserved requests.
"""
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FNOConfig, fno_forward, init_params
from repro.core.partition import make_mesh
from repro.data.loader import Normalizer
from repro.serve import (
    FNORunner, GeomodelCache, GeomodelEntry, ScenarioRequest, Scheduler,
    content_key,
)

# Tiny FNO with 2 static (geomodel) + 1 dynamic channel; module-level so
# the jit cache persists across hypothesis examples.
N_STATIC = 2
CFG = FNOConfig(
    grid=(8, 4, 4, 2), modes=(2, 2, 2, 1), width=2, n_blocks=2,
    decoder_dim=4, in_channels=N_STATIC + 1,
)
PARAMS = init_params(jax.random.PRNGKey(3), CFG)
BUCKET = 4
X_STATS = {"mean": [0.2, -0.4, 0.1], "std": [0.7, 1.3, 0.8]}
Y_STATS = {"mean": [0.1], "std": [0.8]}


def _make_runner(**kw):
    kw.setdefault("max_slots", BUCKET)
    kw.setdefault("buckets", (BUCKET,))
    return FNORunner(
        CFG,
        PARAMS,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        x_normalizer=Normalizer.from_stats(X_STATS, "meanstd"),
        y_normalizer=Normalizer.from_stats(Y_STATS, "meanstd"),
        n_static=N_STATIC,
        **kw,
    )


RUNNER = _make_runner(cache=GeomodelCache())

# a small pool of geomodels so hypothesis examples exercise SHARING
GEOMODELS = [
    np.random.default_rng(100 + g)
    .normal(size=(N_STATIC,) + CFG.grid)
    .astype(np.float32)
    for g in range(3)
]


def _scenario(rid: int, geo: int, steps: int = 1) -> ScenarioRequest:
    rng = np.random.default_rng(1000 + rid)
    dyn = rng.normal(size=(1,) + CFG.grid).astype(np.float32)
    x = np.concatenate([GEOMODELS[geo], dyn], axis=0)
    return ScenarioRequest(rid=rid, x=x, steps=steps)


def _serve(runner, requests, max_slots, interleave=0, split=None):
    sched = Scheduler(runner, max_slots)
    split = len(requests) if split is None else min(split, len(requests))
    for r in requests[:split]:
        sched.submit(r)
    for _ in range(interleave):
        sched.step()
    for r in requests[split:]:
        sched.submit(r)
    done = sched.run_until_done(max_steps=500)
    assert len(done) == len(requests)
    return done, sched


# ---------------------------------------------------------------------------
# Tentpole property: warm cache is bitwise-invisible in the outputs.
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    geos=st.lists(st.integers(0, 2), min_size=1, max_size=7),
    max_slots=st.integers(1, BUCKET),
    split=st.integers(0, 7),
    steps=st.integers(1, 3),
    interleave=st.integers(0, 3),
)
def test_warm_cache_bitwise_identical_to_cold(
    geos, max_slots, split, steps, interleave
):
    """Cold (cache disabled) and warm (shared cache) serving of the same
    mixed-geomodel ensemble produce bit-identical outputs per request."""
    RUNNER.cache = None
    cold, _ = _serve(
        RUNNER, [_scenario(i, g, steps) for i, g in enumerate(geos)],
        max_slots, interleave, split,
    )
    RUNNER.cache = GeomodelCache()
    warm, _ = _serve(
        RUNNER, [_scenario(i, g, steps) for i, g in enumerate(geos)],
        max_slots, interleave, split,
    )
    assert RUNNER.cache.stats["misses"] == len(set(geos))
    for rc, rw in zip(
        sorted(cold, key=lambda r: r.rid), sorted(warm, key=lambda r: r.rid)
    ):
        assert rc.rid == rw.rid and len(rc.outputs) == len(rw.outputs) == steps
        for yc, yw in zip(rc.outputs, rw.outputs):
            np.testing.assert_array_equal(yc, yw)


def test_cache_hit_rate_counts_requests_and_rollout_steps():
    """One shared geomodel, N scenarios x S steps: lookups happen per slot
    per tick, so exactly one miss and N*S - 1 hits."""
    RUNNER.cache = GeomodelCache()
    n, steps = 6, 2
    _serve(RUNNER, [_scenario(i, 0, steps) for i in range(n)], BUCKET)
    s = RUNNER.cache.stats
    assert (s["misses"], s["hits"]) == (1, n * steps - 1)
    assert s["hit_rate"] == pytest.approx(1 - 1 / (n * steps))


def test_split_forward_matches_fused_to_tolerance():
    """The split (prelift + dynamic lift) path equals the fused single-
    encoder forward up to float summation order."""
    fwd = jax.jit(lambda p, x: fno_forward(p, x, CFG))
    for i in range(4):
        req = _scenario(i, i % 3)
        done, _ = _serve(RUNNER, [req], 1)
        xe = RUNNER.x_normalizer.encode(np.asarray(req.x, np.float32)[None])
        expected = RUNNER.y_normalizer.decode(np.asarray(fwd(PARAMS, xe)))[0]
        np.testing.assert_allclose(req.prediction, expected, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Scheduler dedup: identical in-flight requests ride one slot.
# ---------------------------------------------------------------------------

def test_dedup_fans_out_primary_outputs_to_followers():
    base = _scenario(0, 0, steps=2)
    dups = [
        ScenarioRequest(rid=i, x=base.x.copy(), steps=2) for i in (1, 2)
    ]
    other = _scenario(3, 1, steps=2)
    done, sched = _serve(RUNNER, [base, *dups, other], 2)
    assert sched.dedup_attached == 2
    # followers never occupied a slot: 3-deep identical work took the
    # engine steps of 2 distinct requests in 2 slots
    assert sched.steps == 2
    for d in dups:
        assert d.done and len(d.outputs) == 2
        for got, exp in zip(d.outputs, base.outputs):
            np.testing.assert_array_equal(got, exp)
    assert not np.array_equal(other.prediction, base.prediction)


def test_dedup_respects_rollout_length_and_opt_out():
    """Same content but different steps is NOT identical work; dedup=False
    disables attaching entirely."""
    base = _scenario(0, 0, steps=1)
    longer = ScenarioRequest(rid=1, x=base.x.copy(), steps=2)
    done, sched = _serve(RUNNER, [base, longer], 2)
    assert sched.dedup_attached == 0
    assert len(base.outputs) == 1 and len(longer.outputs) == 2

    twin = ScenarioRequest(rid=2, x=base.x.copy(), steps=1)
    sched = Scheduler(RUNNER, 2, dedup=False)
    sched.submit(base)
    sched.submit(twin)
    assert sched.run_until_done(max_steps=50) and sched.dedup_attached == 0


# ---------------------------------------------------------------------------
# LRU eviction under the byte budget.
# ---------------------------------------------------------------------------

def _entry(seed: int) -> GeomodelEntry:
    arr = np.random.default_rng(seed).normal(size=(4, 4)).astype(np.float32)
    return GeomodelEntry(content_key(arr), arr, arr * 2.0)


def test_eviction_respects_byte_budget_lru_first():
    e = [_entry(i) for i in range(4)]
    per = e[0].nbytes
    cache = GeomodelCache(max_bytes=2 * per)  # room for exactly two
    cache.put(e[0].key, e[0])
    cache.put(e[1].key, e[1])
    assert len(cache) == 2 and cache.bytes == 2 * per
    assert cache.get(e[0].key) is e[0]  # touch: e[1] is now LRU
    cache.put(e[2].key, e[2])
    assert cache.get(e[1].key) is None  # evicted LRU-first
    assert cache.get(e[0].key) is e[0] and cache.get(e[2].key) is e[2]
    assert cache.bytes <= cache.max_bytes and cache.evictions == 1
    # an entry larger than the whole budget: strict budget, caller keeps
    # its own reference (returned), nothing retained
    big_arr = np.zeros((64, 64), np.float32)
    big = GeomodelEntry(content_key(big_arr), big_arr, big_arr)
    assert cache.put(big.key, big) is big
    assert cache.get(big.key) is None and cache.bytes <= cache.max_bytes
    # re-putting an existing key refreshes, never double-counts
    cache.put(e[0].key, e[0])
    assert cache.bytes <= 2 * per
    with pytest.raises(ValueError, match="max_bytes"):
        GeomodelCache(max_bytes=0)


def test_eviction_never_invalidates_served_requests():
    """A budget that can hold only ONE geomodel still serves a two-geomodel
    ensemble correctly: slots keep their own entry references."""
    one = GEOMODELS[0].nbytes // N_STATIC * (N_STATIC + CFG.width) + 1
    RUNNER.cache = GeomodelCache(max_bytes=one)
    geos = [0, 1, 0, 1, 0, 1]
    done, _ = _serve(RUNNER, [_scenario(i, g, 2) for i, g in enumerate(geos)], BUCKET)
    assert RUNNER.cache.evictions > 0
    RUNNER.cache = None
    cold, _ = _serve(RUNNER, [_scenario(i, g, 2) for i, g in enumerate(geos)], BUCKET)
    for rw, rc in zip(done, cold):
        for yw, yc in zip(rw.outputs, rc.outputs):
            np.testing.assert_array_equal(yw, yc)


def test_datagen_geomodel_prepends_shared_static_channel(tmp_path):
    """``datagen --geomodel`` writes a 2-channel x store whose leading
    channel is the SAME log-permeability realization in every sample —
    the content the serving cache keys on."""
    from repro.data import ArrayStore
    from repro.launch.datagen import geomodel_channel, main as datagen

    d = str(tmp_path / "ds")
    datagen([
        "--pde", "two_phase", "--n", "2", "--grid", "8", "8", "4",
        "--nt", "2", "--out", d, "--backend", "thread", "--workers", "2",
        "--geomodel",
    ])
    xs = ArrayStore.open(f"{d}/x")
    assert xs.shape[1] == 2 and len(xs.meta["stats"]["mean"]) == 2
    full = xs.read_slice((slice(0, 2),) + (slice(None),) * 5)
    np.testing.assert_array_equal(full[0, 0], full[1, 0])  # shared geomodel
    np.testing.assert_array_equal(full[0, 0], geomodel_channel((8, 8, 4), 2)[0])
    assert full[0, 0].std() > 0  # a real field, not a constant fill


def test_content_key_discriminates():
    a = np.arange(8, dtype=np.float32)
    assert content_key(a) == content_key(a.copy())
    assert content_key(a) != content_key(a.astype(np.float64))
    assert content_key(a) != content_key(a.reshape(2, 4))
    b = a.copy()
    b[3] = np.nextafter(b[3], np.float32(np.inf))  # one-ulp flip
    assert content_key(a) != content_key(b)


# ---------------------------------------------------------------------------
# Lifecycle regressions.
# ---------------------------------------------------------------------------

def test_failing_admit_marks_failed_and_pool_stays_serviceable():
    bad = ScenarioRequest(rid=0, x=_scenario(0, 0).x, steps=0)  # admit raises
    wrong_shape = ScenarioRequest(
        rid=1, x=np.zeros((CFG.in_channels, 2, 2, 2, 2), np.float32)
    )
    good = [_scenario(i, 0) for i in range(2, 5)]
    sched = Scheduler(RUNNER, 2)
    for r in (bad, wrong_shape, *good):
        sched.submit(r)
    done = sched.run_until_done(max_steps=50)
    assert sorted(r.rid for r in done) == [2, 3, 4]
    assert sorted(r.rid for r in sched.failed) == [0, 1]
    for r in sched.failed:
        assert r.done and r.error is not None
        with pytest.raises(RuntimeError, match=f"request {r.rid} failed"):
            r.prediction
    assert sched.pending() == 0


def test_failing_primary_fails_its_followers():
    bad = ScenarioRequest(rid=0, x=_scenario(0, 0).x, steps=0)
    twin = ScenarioRequest(rid=1, x=bad.x.copy(), steps=0)
    sched = Scheduler(RUNNER, 2)
    sched.submit(bad)
    sched.submit(twin)
    assert sched.dedup_attached == 1
    sched.run_until_done(max_steps=50)
    assert sorted(r.rid for r in sched.failed) == [0, 1]
    assert twin.error is not None and sched.pending() == 0


def test_bucket_ladder_must_cover_max_slots_at_construction():
    with pytest.raises(ValueError, match="largest bucket"):
        _make_runner(max_slots=8, buckets=(2, 4))


def test_run_until_done_warns_on_exhausted_max_steps():
    sched = Scheduler(RUNNER, 1)
    reqs = [_scenario(i, 0, steps=3) for i in range(2)]
    for r in reqs:
        sched.submit(r)
    with pytest.warns(RuntimeWarning, match="max_steps=2 exhausted.*2 request"):
        done = sched.run_until_done(max_steps=2)
    assert len(done) < 2
    unserved = next(r for r in reqs if not r.outputs)
    with pytest.raises(RuntimeError, match="no completed rollout steps"):
        unserved.prediction
    # the drained remainder finishes on a fresh budget
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sched.steps = 0
        assert len(sched.run_until_done(max_steps=50)) == 2
