"""Online/streaming training: store visibility, StreamingSchedule replay,
fault-supervisor integration, and the satellite correctness fixes
(metrics-log dedup after restore, stale-store refusal, stepped-slice
rejection, straggler speculation only on started tasks).
"""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core.partition import make_mesh
from repro.data import ArrayStore, ShardedDatasetLoader, StreamingSchedule
from jax.sharding import PartitionSpec as P

SPEC6 = P(("data",), None, None, None, None, None)
SHAPE = (8, 1, 4, 4, 2, 2)
CHUNKS = (1, 1, 2, 4, 2, 2)


def _sample(i: int) -> np.ndarray:
    return np.random.default_rng(1000 + i).normal(size=SHAPE[1:]).astype(np.float32)


def _writer(store: ArrayStore, order, delay_s: float = 0.0):
    """Background 'simulator': publish samples one by one in ``order``."""
    def run():
        for i in order:
            if delay_s:
                time.sleep(delay_s)
            store.write_sample(i, _sample(i))
    th = threading.Thread(target=run, daemon=True)
    th.start()
    return th


# ---------------------------------------------------------------------------
# Store visibility API
# ---------------------------------------------------------------------------

def test_complete_watermark_is_prefix_length():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        assert store.complete_watermark() == 0
        # out-of-order publishes: watermark tracks the COMPLETE PREFIX
        for i in (0, 1, 3):
            store.write_sample(i, _sample(i))
        assert store.complete_watermark() == 2
        assert store.n_complete() == 3  # n_complete counts all, not prefix
        store.write_sample(2, _sample(2))
        assert store.complete_watermark() == 4
        # a partially-written sample does not advance the watermark
        store.write_chunk((4, 0, 0, 0, 0, 0), _sample(4)[None, :, :2, :4])
        assert store.complete_watermark() == 4


def test_wait_for_samples_blocks_and_times_out():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        with pytest.raises(TimeoutError, match="waited"):
            store.wait_for_samples(1, timeout=0.05, poll_s=0.01)
        th = _writer(store, range(SHAPE[0]), delay_s=0.01)
        assert store.wait_for_samples(2, timeout=30.0, poll_s=0.01) >= 2
        th.join()
        # k beyond the store clamps to the store size
        assert store.wait_for_samples(10 ** 6, timeout=30.0) == SHAPE[0]


def test_read_slice_rejects_stepped_slices():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", (2, 8), "f4", (1, 4))
        for i in range(2):
            store.write_sample(i, np.ones(8, np.float32))
        with pytest.raises(ValueError, match="unit-step"):
            store.read_slice((slice(0, 2), slice(0, 8, 2)))
        with pytest.raises(ValueError, match="unit-step"):
            store.read_slice((slice(None, None, -1), slice(0, 8)))


# ---------------------------------------------------------------------------
# StreamingSchedule: visibility, back-pressure, bit-identical replay
# ---------------------------------------------------------------------------

def test_streaming_schedule_draws_only_visible_and_replays():
    """The core online-training property: every batch is drawn from the
    then-visible prefix, and the recorded watermark log replayed against the
    FINISHED store reproduces the whole run bit-identically."""
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        th = _writer(store, range(SHAPE[0]), delay_s=0.03)
        sched = StreamingSchedule([store], batch_size=2, seed=7, poll_s=0.005)
        mesh = make_mesh((1,), ("data",))
        online_ids, online_batches = [], []
        with ShardedDatasetLoader(
            {"x": store}, mesh, 2, {"x": SPEC6}, normalize=(), prefetch=2,
            schedule=sched,
        ) as loader:
            for step in range(10):
                online_batches.append(np.asarray(loader.batch(step)["x"]))
                online_ids.append(sched.sample_ids(step))  # pure -> re-callable
        th.join()
        for step, ids in enumerate(online_ids):
            w = sched.watermark_log[step]
            assert (ids < w).all(), (step, ids, w)  # never an unpublished sample

        # replay: same seed + watermark log, against the completed store
        replay = StreamingSchedule(
            [store], batch_size=2, seed=7, watermark_log=sched.watermark_log
        )
        with ShardedDatasetLoader(
            {"x": store}, mesh, 2, {"x": SPEC6}, normalize=(), prefetch=0,
            schedule=replay,
        ) as loader2:
            for step in range(10):
                np.testing.assert_array_equal(replay.sample_ids(step), online_ids[step])
                np.testing.assert_array_equal(
                    np.asarray(loader2.batch(step)["x"]), online_batches[step]
                )


def test_streaming_schedule_backpressure_counts_stalls():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        sched = StreamingSchedule(
            [store], batch_size=2, seed=0, poll_s=0.005, timeout=30.0
        )
        th = _writer(store, range(3), delay_s=0.05)
        ids = sched.sample_ids(0)  # must block until 2 samples exist
        th.join()
        assert sched.metrics()["stalls"] >= 1
        assert sched.metrics()["stall_s"] > 0
        assert (ids < sched.watermark_log[0]).all()


def test_streaming_schedule_log_persistence_survives_restart():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        for i in range(3):
            store.write_sample(i, _sample(i))
        log = os.path.join(d, "watermarks.json")
        s1 = StreamingSchedule([store], batch_size=2, seed=3, log_path=log)
        first = [s1.sample_ids(t) for t in range(4)]
        # more samples land; a RESTARTED schedule must replay the old
        # watermarks from disk, not observe the new visibility
        for i in range(3, 8):
            store.write_sample(i, _sample(i))
        s2 = StreamingSchedule([store], batch_size=2, seed=3, log_path=log)
        for t in range(4):
            np.testing.assert_array_equal(s2.sample_ids(t), first[t])
        s2.sample_ids(4)  # an unrecorded step observes the NEW visibility
        assert s2.watermark_log[4] == 8 and s1.watermark_log[0] == 3


def test_streaming_schedule_small_prefix_uses_replacement():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        store.write_sample(0, _sample(0))
        sched = StreamingSchedule([store], batch_size=4, seed=0, min_visible=1)
        ids = sched.sample_ids(0)
        assert len(ids) == 4 and (ids == 0).all()


def test_streaming_schedule_batch_larger_than_dataset_terminates():
    """min_visible clamps to the store size: a batch bigger than the whole
    dataset oversamples the full prefix instead of spinning forever."""
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", (2,) + SHAPE[1:], "f4", CHUNKS)
        for i in range(2):
            store.write_sample(i, _sample(i))
        sched = StreamingSchedule([store], batch_size=5, seed=0, timeout=30.0)
        ids = sched.sample_ids(0)
        assert len(ids) == 5 and set(ids) <= {0, 1}
        assert sched.watermark_log[0] == 2


# ---------------------------------------------------------------------------
# Fault supervisor: metrics dedup + kill-mid-generation restart
# ---------------------------------------------------------------------------

def test_restore_replay_does_not_duplicate_metrics():
    import jax.numpy as jnp
    from repro.train.fault import FaultInjector, run_supervised

    def init_state():
        return {"w": jnp.zeros(2)}

    def train_step(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return {"w": w}, {"loss": jnp.sum((w - batch) ** 2)}

    with tempfile.TemporaryDirectory() as d:
        res = run_supervised(
            init_state=init_state,
            train_step=train_step,
            batch_iter=lambda step: jnp.asarray([1.0, 2.0]),
            total_steps=20,
            ckpt_dir=d,
            save_every=5,
            injector=FaultInjector([7, 13]),
        )
    steps = [s for s, _ in res.metrics_log]
    assert res.failures == 2 and res.restores == 2
    assert len(steps) == len(set(steps)) == 20, "duplicate (step, metrics) entries"
    assert steps == sorted(steps)


@pytest.mark.timeout(300)
def test_online_training_survives_kill_mid_generation():
    """End to end through run_supervised: the simulator is still writing,
    a fault kills training mid-run, and the restore replays the SAME sample
    schedule (recorded watermarks) for the re-executed steps."""
    import jax.numpy as jnp
    from repro.train.fault import FaultInjector, run_supervised

    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", SHAPE, "f4", CHUNKS)
        th = _writer(store, range(SHAPE[0]), delay_s=0.05)
        sched = StreamingSchedule([store], batch_size=2, seed=11, poll_s=0.005)
        mesh = make_mesh((1,), ("data",))
        seen = {}

        with ShardedDatasetLoader(
            {"x": store}, mesh, 2, {"x": SPEC6}, normalize=(), prefetch=2,
            schedule=sched,
        ) as loader:

            def batch_iter(step):
                ids = sched.sample_ids(step)
                if step in seen:  # replay after restore: bit-identical
                    np.testing.assert_array_equal(ids, seen[step])
                seen[step] = ids
                return loader.batch(step)

            def init_state():
                return {"w": jnp.zeros(())}

            def train_step(state, batch):
                x = jnp.asarray(batch["x"])
                w = state["w"] - 0.05 * (state["w"] - jnp.mean(x))
                return {"w": w}, {"loss": (state["w"] - jnp.mean(x)) ** 2}

            res = run_supervised(
                init_state=init_state,
                train_step=train_step,
                batch_iter=batch_iter,
                total_steps=12,
                ckpt_dir=os.path.join(d, "ckpt"),
                save_every=4,
                injector=FaultInjector([6]),
            )
        th.join()
    assert res.final_step == 12 and res.failures == 1 and res.restores == 1
    steps = [s for s, _ in res.metrics_log]
    assert len(steps) == len(set(steps)) == 12
    assert all(np.isfinite(m["loss"]) for _, m in res.metrics_log)


# ---------------------------------------------------------------------------
# Datagen satellites: stale-store refusal, incremental stats
# ---------------------------------------------------------------------------

def test_open_or_create_refuses_stale_chunks():
    from repro.launch.datagen import open_or_create

    with tempfile.TemporaryDirectory() as d:
        root = f"{d}/x"
        store = ArrayStore.create(root, (2, 8), "f4", (1, 4))
        store.write_sample(0, np.ones(8, np.float32))
        with pytest.raises(SystemExit, match="chunk file"):
            open_or_create(root, (2, 8), (1, 4), resume=False)
        # --resume (same geometry) still opens it
        assert open_or_create(root, (2, 8), (1, 4), resume=True).sample_complete(0)
        # an empty/meta-only root is fine to (re)create
        empty = f"{d}/y"
        ArrayStore.create(empty, (2, 8), "f4", (1, 4))
        open_or_create(empty, (2, 8), (1, 4), resume=False)


def test_datagen_resume_refuses_mismatched_run_signature():
    """--resume may only continue a run with the same (pde, seed, ...)
    signature — otherwise stale samples from the old run would silently mix
    with the new distribution (task args are pure in the sample index)."""
    from repro.launch.datagen import main as datagen_main

    with tempfile.TemporaryDirectory() as d:
        argv = [
            "--pde", "two_phase", "--n", "2", "--grid", "8", "8", "4",
            "--nt", "2", "--out", f"{d}/ds", "--backend", "thread",
            "--workers", "2", "--resume",
        ]
        assert datagen_main(argv + ["--seed", "0"]) == 2
        with pytest.raises(SystemExit, match="refusing to mix"):
            datagen_main(argv + ["--seed", "1"])
        assert datagen_main(argv + ["--seed", "0"]) == 2  # same run: fine


def test_datagen_incremental_stats_exist_before_finish():
    """The online contract: stats are persisted every --stats-every samples,
    so a trainer can normalize long before the dataset is complete; the
    incremental result matches the full streaming pass."""
    from repro.launch.datagen import main as datagen_main

    with tempfile.TemporaryDirectory() as d:
        out = f"{d}/ds"
        datagen_main([
            "--pde", "two_phase", "--n", "5", "--grid", "8", "8", "4",
            "--nt", "2", "--out", out, "--backend", "thread",
            "--workers", "2", "--stats-every", "2",
        ])
        from repro.launch.datagen import compute_store_stats

        for name in ("x", "y"):
            store = ArrayStore.open(f"{out}/{name}")
            direct = compute_store_stats(store)
            np.testing.assert_allclose(
                store.meta["stats"]["mean"], direct["mean"], rtol=1e-6
            )
            np.testing.assert_allclose(
                store.meta["stats"]["std"], direct["std"], rtol=1e-5
            )
            assert store.meta["stats"]["n_samples"] == 5


# ---------------------------------------------------------------------------
# Cloud satellite: speculation only on tasks that actually STARTED
# ---------------------------------------------------------------------------

def _quick_task(s):
    time.sleep(s)
    return s


@pytest.mark.timeout(120)
def test_speculative_skips_queued_tasks():
    """One worker, one slow task, many queued quick tasks: the quick tasks
    wait a long time from SUBMISSION but run fast once started — the old
    submitted_at-based straggler test would backup-submit all of them."""
    from repro.cloud import BatchPool, ThreadBackend

    with tempfile.TemporaryDirectory() as d:
        pool = BatchPool(
            ThreadBackend(1), store_root=f"{d}/blobs", n_vms=1
        )
        try:
            # quick tasks queue ~0.8s behind the slow one — far beyond the
            # straggler threshold (10 x 0.02s median) measured from SUBMIT,
            # but well under it measured from their actual start
            durations = [0.8] + [0.02] * 6
            results = pool.map(
                _quick_task, [(s,) for s in durations],
                speculative=True, straggler_factor=10.0,
            )
        finally:
            pool.shutdown()
        assert results == durations
        rep = pool.cost_report()
        assert rep["speculated"] == 0, "queued tasks were treated as stragglers"
        # the backend's actual start time is propagated on finish
        assert all(r.started is not None for r in pool.records.values())
        assert all(
            r.started >= r.submitted_at - 1e-3 for r in pool.records.values()
        )


# ---------------------------------------------------------------------------
# train.py satellite: --devices parsing handles both forms
# ---------------------------------------------------------------------------

def test_sniff_devices_both_forms():
    from repro.launch.train import sniff_devices

    assert sniff_devices(["train.py", "--devices", "8"]) == "8"
    assert sniff_devices(["train.py", "--devices=8"]) == "8"
    assert sniff_devices(["train.py", "--devices=16", "--steps", "2"]) == "16"
    assert sniff_devices(["train.py", "--steps", "2"]) is None
