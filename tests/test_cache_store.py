"""Fleet-shared geomodel cache store: the disaggregated tier behind the
per-replica ``GeomodelCache``.

Covers both backends (shared dict + atomic-rename npz files) against the
store contract:
  * roundtrip of full (4-level) and shallow (prelift-only) entries;
  * version namespacing — replicas serving different checkpoints (or a
    different cache level) can never exchange intermediates, and
    ``FNORunner.cache_version`` produces those namespaces;
  * never-downgrade — a shallow put cannot strip deep levels a deeper
    replica already published;
  * isolation — returned/stored arrays are copies (dict backend), corrupt
    files are a miss and removed (file backend);
  * the fleet property: after the pinned replica fails mid-serving, the
    failover replica's LOCAL cache is cold but its store lookup hits, and
    post-failover outputs are bitwise-identical to the originals.
"""
import os

import jax
import numpy as np
import pytest

from repro.core import FNOConfig, init_params
from repro.core.partition import make_mesh
from repro.data.loader import Normalizer
from repro.serve import (
    DictCacheStore, FileCacheStore, FNORunner, Gateway, GeomodelCache,
    GeomodelEntry, ScenarioRequest, content_key, open_cache_store,
)

N_STATIC = 2
CFG = FNOConfig(
    grid=(8, 4, 4, 2), modes=(2, 2, 2, 1), width=2, n_blocks=2,
    decoder_dim=4, in_channels=N_STATIC + 1,
)
PARAMS = init_params(jax.random.PRNGKey(3), CFG)
X_STATS = {"mean": [0.2, -0.4, 0.1], "std": [0.7, 1.3, 0.8]}
Y_STATS = {"mean": [0.1], "std": [0.8]}

GEOMODEL = (
    np.random.default_rng(42)
    .normal(size=(N_STATIC,) + CFG.grid)
    .astype(np.float32)
)


def _entry(seed: int, deep: bool = True) -> GeomodelEntry:
    rng = np.random.default_rng(seed)
    arr = rng.normal(size=(3, 4)).astype(np.float32)
    pre = rng.normal(size=(2, 4)).astype(np.float32)
    spec = contrib = None
    if deep:
        spec = (
            rng.normal(size=(2, 3)) + 1j * rng.normal(size=(2, 3))
        ).astype(np.complex64)
        contrib = (spec * 1.5).astype(np.complex64)
    return GeomodelEntry(content_key(arr), arr, pre, spec, contrib)


@pytest.fixture(params=["dict", "file"])
def store(request, tmp_path):
    if request.param == "dict":
        return DictCacheStore()
    return FileCacheStore(str(tmp_path / "store"))


def test_roundtrip_full_and_shallow_entries(store):
    full, shallow = _entry(0), _entry(1, deep=False)
    store.put("v1", full.key, full)
    store.put("v1", shallow.key, shallow)
    got = store.get("v1", full.key)
    for name in ("normalized", "prelift", "spectra", "contribution"):
        np.testing.assert_array_equal(getattr(got, name), getattr(full, name))
    assert got.spectra.dtype == np.complex64
    got_s = store.get("v1", shallow.key)
    assert got_s.spectra is None and got_s.contribution is None
    np.testing.assert_array_equal(got_s.prelift, shallow.prelift)
    s = store.stats
    assert s["hits"] == 2 and s["puts"] == 2 and s["entries"] == 2
    assert s["bytes"] > 0 and s["hit_rate"] == 1.0


def test_version_namespaces_are_isolated(store):
    e = _entry(2)
    store.put("ckpt-a", e.key, e)
    assert store.get("ckpt-b", e.key) is None
    assert store.get("ckpt-a", e.key) is not None
    assert store.stats["misses"] == 1


def test_store_never_downgrades_a_fuller_entry(store):
    full = _entry(3)
    store.put("v", full.key, full)
    store.put("v", full.key, full.without_deep())  # ignored: shallower
    assert store.get("v", full.key).contribution is not None
    # but a deeper put DOES replace a shallow entry
    e2 = _entry(4)
    store.put("v", e2.key, e2.without_deep())
    store.put("v", e2.key, e2)
    assert store.get("v", e2.key).contribution is not None


def test_dict_backend_stores_and_returns_copies():
    store = DictCacheStore()
    e = _entry(5)
    ref = e.normalized.copy()
    store.put("v", e.key, e)
    e.normalized[:] = -1.0  # mutate the caller's arrays after put
    got = store.get("v", e.key)
    np.testing.assert_array_equal(got.normalized, ref)
    got.normalized[:] = -2.0  # mutate a returned array
    np.testing.assert_array_equal(store.get("v", e.key).normalized, ref)


def test_file_backend_corrupt_entry_is_miss_and_removed(tmp_path):
    store = FileCacheStore(str(tmp_path))
    e = _entry(6)
    store.put("v", e.key, e)
    path = store._path("v", e.key)
    with open(path, "wb") as f:
        f.write(b"not an npz")
    assert store.get("v", e.key) is None
    assert not os.path.exists(path)  # corrupt file cleaned up
    assert store.stats["misses"] == 1
    # a fresh put rewrites it
    store.put("v", e.key, e)
    assert store.get("v", e.key) is not None


def test_open_cache_store_spec(tmp_path):
    assert isinstance(open_cache_store("dict"), DictCacheStore)
    assert isinstance(open_cache_store("mem"), DictCacheStore)
    fs = open_cache_store(str(tmp_path / "root"))
    assert isinstance(fs, FileCacheStore)
    assert os.path.isdir(fs.root)


# ---------------------------------------------------------------------------
# Runner integration: version signature + fleet failover reuse.
# ---------------------------------------------------------------------------

def _runner(level="deep", store=None, params=None):
    return FNORunner(
        CFG,
        PARAMS if params is None else params,
        mesh=make_mesh((1,), ("data",)),
        model_axis=None,
        max_slots=4,
        buckets=(4,),
        x_normalizer=Normalizer.from_stats(X_STATS, "meanstd"),
        y_normalizer=Normalizer.from_stats(Y_STATS, "meanstd"),
        n_static=N_STATIC,
        cache=GeomodelCache(),
        cache_level=level,
        cache_store=store,
    )


def _scenario(rid: int, steps: int = 1) -> ScenarioRequest:
    rng = np.random.default_rng(1000 + rid)
    dyn = rng.normal(size=(1,) + CFG.grid).astype(np.float32)
    return ScenarioRequest(
        rid=rid, x=np.concatenate([GEOMODEL, dyn], axis=0), steps=steps
    )


def test_cache_version_namespaces_by_level_and_checkpoint():
    """Same config + params -> same version (replicas share entries);
    different cache level or different weights -> different version."""
    a, b = _runner(), _runner()
    assert a.cache_version == b.cache_version
    assert a.cache_version != _runner(level="prelift").cache_version
    other = init_params(jax.random.PRNGKey(9), CFG)
    assert a.cache_version != _runner(params=other).cache_version


def test_store_populates_local_cache_without_recompute():
    """A replica that was never warmed serves from the store: its local
    cache fills from the store entry and outputs match bitwise."""
    store = DictCacheStore()
    warmed, fresh = _runner(store=store), _runner(store=store)
    ref = [_scenario(i, 2) for i in range(3)]
    from repro.serve import Scheduler

    sched = Scheduler(warmed, 4)
    for r in ref:
        sched.submit(r)
    sched.run_until_done(max_steps=100)
    assert store.puts == 1 and store.hits == 0
    got = [_scenario(i, 2) for i in range(3)]
    sched2 = Scheduler(fresh, 4)
    for r in got:
        sched2.submit(r)
    sched2.run_until_done(max_steps=100)
    assert store.hits >= 1  # local miss -> store hit, no host recompute
    assert fresh.cache.stats["entries"] == 1
    for a, b in zip(ref, got):
        for ya, yb in zip(a.outputs, b.outputs):
            np.testing.assert_array_equal(ya, yb)


def test_store_keeps_geomodel_warm_across_replica_failover(tmp_path):
    """Affinity pins the ensemble to one replica, warming its local cache
    AND the file store. That replica then dies mid-wave; the failover
    replica's local cache is cold but the store lookup hits — and the
    re-served outputs are bitwise-identical to the pre-failure wave."""
    store = FileCacheStore(str(tmp_path / "fleet"))
    gw = Gateway(
        [_runner(store=store), _runner(store=store)], policy="affinity"
    )
    wave1 = [_scenario(i, 2) for i in range(4)]
    for r in wave1:
        gw.submit(r)
    gw.run_until_done(max_steps=200)
    assert all(r.done and r.error is None for r in wave1)
    pinned = max(gw.replicas, key=lambda h: h.routed)
    other = next(h for h in gw.replicas if h is not pinned)
    assert other.routed == 0 and store.puts == 1

    def _dead_step(slots, active):
        raise RuntimeError("simulated replica hardware failure")

    pinned.runner.step = _dead_step
    wave2 = [_scenario(i, 2) for i in range(4)]
    for r in wave2:
        gw.submit(r)
    gw.run_until_done(max_steps=200)
    assert all(r.done and r.error is None for r in wave2)
    assert not pinned.healthy and gw.rerouted > 0
    assert store.hits >= 1, store.stats  # the survivor hit the SHARED tier
    assert other.runner.cache.stats["entries"] == 1
    for a, b in zip(wave1, wave2):
        assert len(a.outputs) == len(b.outputs) == 2
        for ya, yb in zip(a.outputs, b.outputs):
            np.testing.assert_array_equal(ya, yb)
    fleet = gw.stats()["fleet"]
    assert fleet["store"] is not None and fleet["store"]["hits"] >= 1
    assert fleet["cache_bytes"] > 0
