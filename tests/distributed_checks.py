"""Distributed-equivalence assertions, run under 8 simulated host devices.

Executed as a subprocess by test_distributed.py (the device-count flag must
be set before jax initializes, so this cannot run inside the main pytest
process, whose device count is environment-dependent).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    FNOConfig, fno_forward, forward_and_specs, init_params, make_dist_forward,
    make_pipeline_forward, param_specs, params_with_planes,
    params_without_planes, repartition, repartition_chunked,
    ulysses_attention,
)
from repro.common.compat import shard_map
from repro.core.partition import make_mesh
from repro.core.ulysses import _dense_attention

CHECKS = []


def check(fn):
    CHECKS.append(fn)
    return fn


@check
def repartition_roundtrip_and_adjoint():
    mesh = make_mesh((8,), ("model",))
    x = jnp.arange(2 * 8 * 16, dtype=jnp.float32).reshape(2, 8, 16) + 1j * 3.0
    x = x.astype(jnp.complex64)

    def rt(a):
        b = repartition(a, src=1, dst=2, axis_name="model")
        return repartition(b, src=2, dst=1, axis_name="model")

    y = jax.jit(shard_map(rt, mesh, P(None, "model", None),
                          P(None, "model", None)))(x)
    assert bool(jnp.all(y == x)), "repartition roundtrip failed"

    # adjoint: <R x, y> == <x, R^T y>
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2, 8, 16))
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    fwd = jax.jit(shard_map(
        lambda t: repartition(t, 1, 2, "model"), mesh,
        P(None, "model", None), P(None, None, "model")))
    bwd = jax.jit(shard_map(
        lambda t: repartition(t, 2, 1, "model"), mesh,
        P(None, None, "model"), P(None, "model", None)))
    lhs = jnp.vdot(fwd(a), fwd(jnp.zeros_like(a)) * 0 + fwd(a) * 0 + fwd(b) * 0 + fwd(b))
    # simpler: <R a, R b> == <a, b> (R is orthogonal permutation)
    lhs = jnp.vdot(fwd(a), fwd(b))
    rhs = jnp.vdot(a, b)
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-5)
    # and R^T R == I
    np.testing.assert_allclose(np.asarray(bwd(fwd(a))), np.asarray(a), rtol=1e-6)


@check
def fno_dist_matches_serial():
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=2, out_channels=1, n_blocks=3, decoder_dim=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 16, 8, 8))
    y_ser = jax.jit(lambda p, x: fno_forward(p, x, cfg))(params, x)
    mesh = make_mesh((2, 4), ("data", "model"))
    for variant in ("paper", "grady31"):
        fwd = make_dist_forward(mesh, cfg, dp_axes=("data",), variant=variant)
        y = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ser), rtol=2e-4, atol=2e-5)
    # gradient equivalence through the distributed path
    g_ser = jax.jit(jax.grad(lambda p: jnp.mean(fno_forward(p, x, cfg) ** 2)))(params)
    fwd = make_dist_forward(mesh, cfg, dp_axes=("data",))
    g_dd = jax.jit(jax.grad(lambda p: jnp.mean(fwd(p, x) ** 2)))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-5),
        g_dd, g_ser,
    )


@check
def fno_dist_2d_pencil_matches_serial():
    """2-D pencil decomposition (2 data x 2 mx x 2 my) == serial oracle."""
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=2, out_channels=1, n_blocks=3, decoder_dim=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 16, 8, 8))
    y_ser = jax.jit(lambda p, x: fno_forward(p, x, cfg))(params, x)
    mesh = make_mesh((2, 2, 2), ("data", "mx", "my"))
    for variant in ("paper", "eager"):
        fwd = make_dist_forward(mesh, cfg, dp_axes=("data",),
                                model_axis=("mx", "my"), variant=variant)
        y = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ser), rtol=2e-4, atol=2e-5)
    # gradient equivalence through both all-to-alls
    g_ser = jax.jit(jax.grad(lambda p: jnp.mean(fno_forward(p, x, cfg) ** 2)))(params)
    fwd = make_dist_forward(mesh, cfg, dp_axes=("data",), model_axis=("mx", "my"))
    g_dd = jax.jit(jax.grad(lambda p: jnp.mean(fwd(p, x) ** 2)))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-5),
        g_dd, g_ser,
    )


@check
def pipeline_matches_serial():
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=1, out_channels=1, n_blocks=4, decoder_dim=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 16, 16, 8, 8))
    y_ser = jax.jit(lambda p, x: fno_forward(p, x, cfg))(params, x)
    mesh = make_mesh((1, 4), ("data", "model"))
    pfwd = make_pipeline_forward(mesh, cfg, n_micro=2)
    y_pp = jax.jit(pfwd)(params, x)
    np.testing.assert_allclose(np.asarray(y_pp), np.asarray(y_ser), rtol=2e-4, atol=2e-5)


@check
def ulysses_matches_dense():
    mesh = make_mesh((8,), ("model",))
    b, s, h, kvh, d = 2, 32, 8, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    ref = _dense_attention(q, k, v, causal=True, scale=None)
    fn = shard_map(
        lambda q, k, v: ulysses_attention(q, k, v, "model", causal=True),
        mesh,
        (P(None, "model"), P(None, "model"), P(None, "model")),
        P(None, "model"),
    )
    out = jax.jit(fn)(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    # GQA path (kvh not divisible by axis -> all-gather branch)
    k2 = k[:, :, :2]
    v2 = v[:, :, :2]
    ref2 = _dense_attention(q, k2, v2, causal=True, scale=None)
    fn2 = shard_map(
        lambda q, k, v: ulysses_attention(q, k, v, "model", causal=True),
        mesh,
        (P(None, "model"), P(None, "model"), P(None, "model")),
        P(None, "model"),
    )
    out2 = jax.jit(fn2)(q, k2, v2)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2), rtol=2e-4, atol=2e-5)


@check
def moe_a2a_matches_local():
    from repro.models.moe import MoEConfig, init_moe_params, moe_apply
    from repro.models.policy import LOCAL, ParallelPolicy

    moe = MoEConfig(n_experts=8, top_k=2, d_expert=16, n_shared=1,
                    capacity_factor=4.0)  # ample capacity -> no drops
    d = 32
    params = init_moe_params(jax.random.PRNGKey(0), d, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    y_local, aux_local = jax.jit(lambda p, x: moe_apply(p, x, moe, LOCAL))(params, x)
    mesh = make_mesh((2, 4), ("data", "model"))
    policy = ParallelPolicy(mesh=mesh, dp_axes=("data",), model_axis="model")
    y_dist, aux_dist = jax.jit(lambda p, x: moe_apply(p, x, moe, policy))(params, x)
    np.testing.assert_allclose(np.asarray(y_dist), np.asarray(y_local), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(float(aux_dist), float(aux_local), rtol=1e-3)


@check
def head_padding_exact():
    """attn_forward with n_heads %% P != 0 (zero-padded heads) == LOCAL."""
    import dataclasses
    from repro.configs import get_arch, reduced
    from repro.models import attention as attn_lib
    from repro.models.policy import LOCAL, ParallelPolicy

    cfg = dataclasses.replace(reduced(get_arch("qwen1.5-32b")), n_heads=6, kv_heads=6)
    p = attn_lib.init_attn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    ref = jax.jit(lambda p, x: attn_lib.attn_forward(p, x, cfg, LOCAL))(p, x)
    mesh = make_mesh((1, 4), ("data", "model"))
    pol = ParallelPolicy(mesh=mesh, dp_axes=("data",), model_axis="model")
    out = jax.jit(lambda p, x: attn_lib.attn_forward(p, x, cfg, pol))(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4)


@check
def dist_lm_loss_matches_local():
    """Full LM train loss: pjit on a 2x4 mesh == single-device (same params)."""
    from repro.configs import get_arch, reduced
    from repro.models import init_lm_params, lm_loss
    from repro.models.policy import LOCAL, ParallelPolicy

    for arch in ("chatglm3-6b", "deepseek-moe-16b"):
        cfg = reduced(get_arch(arch))
        params = init_lm_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
        batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
        loss_local, _ = jax.jit(lambda p, b: lm_loss(p, b, cfg, LOCAL))(params, batch)
        mesh = make_mesh((2, 4), ("data", "model"))
        pol = ParallelPolicy(mesh=mesh, dp_axes=("data",), model_axis="model", seq_shard=True)
        loss_dist, _ = jax.jit(lambda p, b: lm_loss(p, b, cfg, pol))(params, batch)
        np.testing.assert_allclose(float(loss_dist), float(loss_local), rtol=3e-3)


@check
def checkpoint_elastic_resharding():
    """Save on a (2,4) mesh, restore onto (4,2) and onto 1 device."""
    import tempfile
    from repro.train import checkpoint as ck

    mesh_a = make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
    tree = {"w": xa, "b": jnp.ones((8,))}
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 3, tree)
        abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        mesh_b = make_mesh((4, 2), ("data", "model"))
        shardings = {
            "w": NamedSharding(mesh_b, P("model", "data")),
            "b": NamedSharding(mesh_b, P()),
        }
        restored, step, _ = ck.restore(d, abstract, shardings=shardings)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
        restored1, _, _ = ck.restore(d, abstract)
        np.testing.assert_array_equal(np.asarray(restored1["w"]), np.asarray(x))


@check
def compressed_allreduce_error_feedback():
    from repro.train.compression import compressed_psum_mean, init_error_state

    mesh = make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))

    def run(gs, ratio):
        def body(g_local, err_local):
            red, new_err = compressed_psum_mean(
                g_local[0], err_local[0], "data", ratio=ratio
            )
            return red, new_err[None]
        return jax.jit(shard_map(
            body, mesh, (P("data", None), P("data", None)),
            (P(None), P("data", None)),
        ))(gs, jnp.zeros((8, 256)))

    # ratio=1.0 -> lossless: equals dense mean
    red, err = run(g, 1.0)
    np.testing.assert_allclose(np.asarray(red), np.asarray(g.mean(0)), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(err), 0.0, atol=1e-6)
    # ratio<1: error feedback retains the residual exactly
    red2, err2 = run(g, 0.1)
    # reduced + mean(err) == dense mean (conservation)
    np.testing.assert_allclose(
        np.asarray(red2 + err2.mean(0)), np.asarray(g.mean(0)), rtol=1e-4, atol=1e-5
    )


@check
def repartition_chunked_bit_identical():
    """Channel-chunked repartition (the all-to-all overlap primitive) is
    pure data movement: bit-identical to the blocking repartition for any
    chunk count, divisible or not, clamped past the extent."""
    mesh = make_mesh((8,), ("model",))
    key = jax.random.PRNGKey(5)
    x = (jax.random.normal(key, (2, 6, 8, 16))
         + 1j * jax.random.normal(jax.random.PRNGKey(6), (2, 6, 8, 16))
         ).astype(jnp.complex64)
    spec_in, spec_out = P(None, None, "model", None), P(None, None, None, "model")
    base = jax.jit(shard_map(
        lambda t: repartition(t, 2, 3, "model"), mesh, spec_in, spec_out))(x)
    for chunks in (1, 2, 3, 6, 16):  # 3 non-divisible; 16 clamps to extent 6
        y = jax.jit(shard_map(
            lambda t, c=chunks: repartition_chunked(
                t, 2, 3, "model", chunks=c, chunk_dim=1),
            mesh, spec_in, spec_out))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(base))


@check
def fno_comm_chunks_matches_unchunked():
    """comm_chunks>1 (channel-chunked all-to-alls through the whole dist
    FFT pipeline) == the unchunked forward; channels are a pure batch dim."""
    import dataclasses
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=2, out_channels=1, n_blocks=2, decoder_dim=8)
    cfg_ck = dataclasses.replace(cfg, comm_chunks=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 16, 8, 8))
    mesh = make_mesh((2, 4), ("data", "model"))
    y0 = jax.jit(make_dist_forward(mesh, cfg, dp_axes=("data",)))(params, x)
    y2 = jax.jit(make_dist_forward(mesh, cfg_ck, dp_axes=("data",)))(params, x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), rtol=1e-6, atol=1e-7)
    mesh2 = make_mesh((2, 2, 2), ("data", "mx", "my"))
    y0 = jax.jit(make_dist_forward(
        mesh2, cfg, dp_axes=("data",), model_axis=("mx", "my")))(params, x)
    y2 = jax.jit(make_dist_forward(
        mesh2, cfg_ck, dp_axes=("data",), model_axis=("mx", "my")))(params, x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), rtol=1e-6, atol=1e-7)


@check
def fno_fused_pallas_matches_serial():
    """The ISSUE's gate: every use_pallas=True dist variant == the UNFUSED
    serial oracle to <= 1e-4, gradients included (interpret-mode kernels)."""
    import dataclasses
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=2, out_channels=1, n_blocks=2, decoder_dim=8,
                    use_pallas=True, comm_chunks=2)
    cfg_ref = dataclasses.replace(cfg, use_pallas=False, comm_chunks=1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 16, 8, 8))
    y_ser = jax.jit(lambda p, x: fno_forward(p, x, cfg_ref))(params, x)

    # serial fused forward + grads
    y_f = jax.jit(lambda p, x: fno_forward(p, x, cfg))(params, x)
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_ser), rtol=1e-4, atol=1e-5)
    g_ser = jax.jit(jax.grad(lambda p: jnp.mean(fno_forward(p, x, cfg_ref) ** 2)))(params)
    g_f = jax.jit(jax.grad(lambda p: jnp.mean(fno_forward(p, x, cfg) ** 2)))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        g_f, g_ser,
    )

    # every 1-D dist variant, fused, vs the serial oracle
    mesh = make_mesh((2, 4), ("data", "model"))
    for variant in ("paper", "eager", "grady31"):
        fwd = make_dist_forward(mesh, cfg, dp_axes=("data",), variant=variant)
        y = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ser), rtol=1e-4, atol=1e-5)

    # 2-D pencils, fused
    mesh2 = make_mesh((2, 2, 2), ("data", "mx", "my"))
    for variant in ("paper", "eager"):
        fwd = make_dist_forward(mesh2, cfg, dp_axes=("data",),
                                model_axis=("mx", "my"), variant=variant)
        y = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ser), rtol=1e-4, atol=1e-5)

    # gradient gate: fused dist vs unfused dist (tight) and vs serial
    fwd_f = make_dist_forward(mesh, cfg, dp_axes=("data",))
    fwd_u = make_dist_forward(mesh, cfg_ref, dp_axes=("data",))
    g_df = jax.jit(jax.grad(lambda p: jnp.mean(fwd_f(p, x) ** 2)))(params)
    g_du = jax.jit(jax.grad(lambda p: jnp.mean(fwd_u(p, x) ** 2)))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        g_df, g_du,
    )
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-5),
        g_df, g_ser,
    )
    # 2-D grads, fused vs serial
    fwd_f2 = make_dist_forward(mesh2, cfg, dp_axes=("data",), model_axis=("mx", "my"))
    g_df2 = jax.jit(jax.grad(lambda p: jnp.mean(fwd_f2(p, x) ** 2)))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-5),
        g_df2, g_ser,
    )


@check
def fno_deep_split_matches_serial():
    """The deep block-input split — a cached first-block kept-mode static
    contribution summed into the dynamic remainder's pre-activation — ==
    the UNFUSED serial oracle to <= 1e-4 through every serving layout:
    serial (unfused + fused), every 1-D dist variant, and 2-D pencils."""
    import dataclasses
    from repro.core import (
        encoder_prelift, fno_forward_deep_split, make_dist_forward_deep_split,
        spectral_prelift,
    )

    n_static = 1
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=2, out_channels=1, n_blocks=2, decoder_dim=8,
                    use_pallas=True, comm_chunks=2)
    cfg_ref = dataclasses.replace(cfg, use_pallas=False, comm_chunks=1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 16, 8, 8))
    y_ser = jax.jit(lambda p, x: fno_forward(p, x, cfg_ref))(params, x)

    xd = x[:, n_static:]
    pre_s = encoder_prelift(params, x[:, :n_static], cfg, slice(0, n_static))
    _, contrib = spectral_prelift(params, pre_s, cfg_ref)

    # serial deep split: unfused, then fused Pallas
    for c in (cfg_ref, cfg):
        y = jax.jit(lambda p, ck, ps, xdyn, c=c: fno_forward_deep_split(
            p, ck, ps, xdyn, c, n_static))(params, contrib, pre_s, xd)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_ser), rtol=1e-4, atol=1e-5)

    # every 1-D dist variant, fused, contrib sharded along k_y
    mesh = make_mesh((2, 4), ("data", "model"))
    for variant in ("paper", "eager", "grady31"):
        fwd = make_dist_forward_deep_split(
            mesh, cfg, n_static, dp_axes=("data",), variant=variant)
        y = jax.jit(fwd)(params, contrib, pre_s, xd)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_ser), rtol=1e-4, atol=1e-5)

    # 2-D pencils, fused, contrib sharded along (k_y, k_z)
    mesh2 = make_mesh((2, 2, 2), ("data", "mx", "my"))
    for variant in ("paper", "eager"):
        fwd = make_dist_forward_deep_split(
            mesh2, cfg, n_static, dp_axes=("data",),
            model_axis=("mx", "my"), variant=variant)
        y = jax.jit(fwd)(params, contrib, pre_s, xd)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_ser), rtol=1e-4, atol=1e-5)


@check
def fno_planes_serving_forward_matches_serial():
    """The serving runner's layout: plane-cached params (w_spec_re/_im)
    through the fused dist forward == the serial oracle on complex params,
    and the planes round-trip (params_without_planes) is exact."""
    cfg = FNOConfig(grid=(16, 16, 8, 8), modes=(4, 4, 2, 3), width=6,
                    in_channels=2, out_channels=1, n_blocks=2, decoder_dim=8,
                    use_pallas=True, comm_chunks=2)
    import dataclasses
    cfg_ref = dataclasses.replace(cfg, use_pallas=False, comm_chunks=1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 16, 8, 8))
    y_ser = jax.jit(lambda p, x: fno_forward(p, x, cfg_ref))(params, x)

    mesh = make_mesh((2, 4), ("data", "model"))
    fwd, x_spec, p_specs = forward_and_specs(
        mesh, cfg, dp_axes=("data",), model_axis="model", planes=True)
    pp = params_with_planes(params)
    assert "w_spec" not in pp["blocks"] and "w_spec_re" in pp["blocks"]
    y = jax.jit(fwd)(pp, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ser), rtol=1e-4, atol=1e-5)

    back = params_without_planes(pp)
    np.testing.assert_array_equal(
        np.asarray(back["blocks"]["w_spec"]), np.asarray(params["blocks"]["w_spec"]))


def main():
    failed = []
    for fn in CHECKS:
        try:
            fn()
            print(f"PASS {fn.__name__}")
        except Exception as e:  # noqa: BLE001
            failed.append((fn.__name__, repr(e)))
            print(f"FAIL {fn.__name__}: {e!r}")
    if failed:
        sys.exit(1)
    print("ALL_DISTRIBUTED_CHECKS_PASSED")


if __name__ == "__main__":
    main()
