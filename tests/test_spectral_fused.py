"""Fused truncate+mix+pad spectral kernel vs the unfused XLA oracle.

Everything runs in Pallas interpret mode on CPU; grids are kept small
(each interpret-mode grid step costs ~ms). Covers the awkward shapes the
parametrized sweeps in test_kernels.py miss: degenerate kept extents
(m=1 and 2m == N), mixed pre-truncated/full dims, rFFT tail padding,
non-divisible block_k on the flattened path, and gradients through the
custom_vjp on both paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.spectral_conv import (
    cached_weight_planes,
    clear_plane_cache,
    plane_cache_stats,
    spectral_apply,
    spectral_apply_fused,
    spectral_apply_fused_ref,
    spectral_apply_ref,
    weight_planes,
)


def _rand_cplx(key, shape):
    ka, kb = jax.random.split(key)
    return (jax.random.normal(ka, shape) + 1j * jax.random.normal(kb, shape)).astype(
        jnp.complex64
    )


def _problem(seed, b, ci, co, dims, t_in, kt, t_out):
    """(xf, w, trunc): dims is a 3-list of either (N, K) full-spectrum pairs
    or (None, K) pre-truncated dims."""
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    trunc = tuple(n for n, _ in dims)
    ext = tuple(k if n is None else n for n, k in dims)
    kept = tuple(k for _, k in dims)
    xf = _rand_cplx(kx, (b, ci) + ext + (t_in,))
    w = _rand_cplx(kw, (ci, co) + kept + (kt,))
    return xf, w, trunc, t_out


# dim strategy: full-spectrum (N, K) with K even, 2 <= K <= N — including
# the degenerate K=2 (m=1) and K=N (2m == N, nothing actually truncated)
# corners — or pre-truncated (None, K) with any small K.
_dim = st.sampled_from(
    [(4, 2), (4, 4), (6, 2), (6, 4), (6, 6), (8, 4), (5, 2), (5, 4), (7, 6),
     (None, 1), (None, 2), (None, 3), (None, 4)]
)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 100),
    b=st.integers(1, 2),
    ci=st.integers(1, 4),
    co=st.integers(1, 4),
    d1=_dim,
    d2=_dim,
    d3=_dim,
    kt=st.integers(1, 3),
    t_extra=st.integers(0, 3),
    pad_t=st.booleans(),
)
def test_fused_hypothesis(seed, b, ci, co, d1, d2, d3, kt, t_extra, pad_t):
    t_in = kt + t_extra
    t_out = t_in if pad_t else None
    xf, w, trunc, t_out = _problem(seed, b, ci, co, [d1, d2, d3], t_in, kt, t_out)
    ref = spectral_apply_fused_ref(xf, w, trunc, t_out)
    out = spectral_apply_fused(xf, w, trunc, t_out=t_out, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_fused_degenerate_modes():
    # m=1 on a truncated dim, 2m == N on another, pre-truncated K=1 third
    xf, w, trunc, t_out = _problem(0, 2, 3, 4, [(6, 2), (4, 4), (None, 1)], 4, 3, 4)
    ref = spectral_apply_fused_ref(xf, w, trunc, t_out)
    out = spectral_apply_fused(xf, w, trunc, t_out=t_out, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_fused_gradients_interpret():
    """Grads flow through the fused custom_vjp in interpret mode and match
    the unfused oracle's — the ISSUE's serial-oracle gate at kernel level."""
    xf, w, trunc, t_out = _problem(3, 2, 3, 3, [(6, 4), (None, 2), (5, 2)], 4, 3, 4)

    def loss(fn):
        def f(xf_, w_):
            y = fn(xf_, w_)
            return jnp.sum(jnp.abs(y) ** 2)
        return f

    fused = loss(lambda x_, w_: spectral_apply_fused(x_, w_, trunc, t_out=t_out, use_pallas=True))
    ref = loss(lambda x_, w_: spectral_apply_fused_ref(x_, w_, trunc, t_out))
    gx_f, gw_f = jax.grad(fused, argnums=(0, 1))(xf, w)
    gx_r, gw_r = jax.grad(ref, argnums=(0, 1))(xf, w)
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_r), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_r), rtol=2e-4, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 50),
    k1=st.integers(1, 6),
    k2=st.integers(1, 5),
    block_k=st.sampled_from([3, 7, 8, 16]),  # 3 and 7 never divide K evenly
)
def test_flat_nondivisible_block_k(seed, k1, k2, block_k):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    xf = _rand_cplx(kx, (2, 3, k1, k2))
    w = _rand_cplx(kw, (3, 4, k1, k2))
    ref = spectral_apply_ref(xf, w)
    out = spectral_apply(xf, w, use_pallas=True, block_k=block_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


def test_flat_gradients_interpret():
    key = jax.random.PRNGKey(7)
    kx, kw = jax.random.split(key)
    xf = _rand_cplx(kx, (2, 3, 4, 3))
    w = _rand_cplx(kw, (3, 4, 4, 3))

    def loss(use_pallas):
        def f(x_, w_):
            y = spectral_apply(x_, w_, use_pallas=use_pallas, block_k=7)
            return jnp.sum(jnp.abs(y) ** 2)
        return f

    gx_p, gw_p = jax.grad(loss(True), argnums=(0, 1))(xf, w)
    gx_r, gw_r = jax.grad(loss(False), argnums=(0, 1))(xf, w)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r), rtol=2e-4, atol=2e-5)


def test_plane_cache_hit_miss_and_inference_path():
    clear_plane_cache()
    xf, w, trunc, t_out = _problem(11, 1, 2, 3, [(4, 2), (None, 2), (4, 4)], 3, 2, 3)
    p1 = cached_weight_planes(w)
    p2 = cached_weight_planes(w)
    assert p1 is p2, "warm hit must return the cached planes object"
    stats = plane_cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1, stats

    wr, wi = weight_planes(w)
    np.testing.assert_allclose(np.asarray(p1[0]), np.asarray(wr))
    np.testing.assert_allclose(np.asarray(p1[1]), np.asarray(wi))

    # planes-tuple inference path (what FNORunner serves) matches the oracle
    ref = spectral_apply_fused_ref(xf, w, trunc, t_out)
    out = spectral_apply_fused(xf, p1, trunc, t_out=t_out, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    clear_plane_cache()
