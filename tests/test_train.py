"""Optimizer / checkpoint / fault-tolerance / schedule tests."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (
    AdamWConfig, adamw_update, init_opt_state, make_train_step,
    opt_state_specs, warmup_cosine, zero1_specs,
)
from repro.train import checkpoint as ck
from repro.train.fault import FaultInjector, StragglerWatchdog, run_supervised


def test_adamw_matches_reference():
    """One AdamW step vs a hand-written numpy reference."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01, grad_clip=None)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    opt = init_opt_state(p)
    new_p, new_opt, _ = adamw_update(g, opt, p, cfg)

    mu = 0.1 * np.asarray(g["w"])
    nu = 0.01 * np.asarray(g["w"]) ** 2
    mu_hat = mu / (1 - 0.9)
    nu_hat = nu / (1 - 0.99)
    expect = np.asarray(p["w"]) - 0.1 * mu_hat / (np.sqrt(nu_hat) + 1e-8)
    expect = expect - 0.1 * 0.01 * np.asarray(p["w"])
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_opt["count"]) == 1


def test_adamw_complex_leaves():
    cfg = AdamWConfig(lr=0.01, grad_clip=1.0)
    p = {"w": (jnp.ones((4,)) + 1j * jnp.ones((4,))).astype(jnp.complex64)}
    g = {"w": (0.1 * jnp.ones((4,)) - 0.2j * jnp.ones((4,))).astype(jnp.complex64)}
    opt = init_opt_state(p)
    assert opt["nu"]["w"].dtype == jnp.float32  # |g|^2 is real
    new_p, new_opt, stats = adamw_update(g, opt, p, cfg)
    assert new_p["w"].dtype == jnp.complex64
    assert bool(jnp.all(jnp.isfinite(new_opt["nu"]["w"])))
    assert float(stats["grad_norm"]) > 0


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, grad_clip=None)
    target = jnp.asarray([1.0, -2.0, 0.5])
    p = {"w": jnp.zeros(3)}
    opt = init_opt_state(p)
    for _ in range(200):
        g = {"w": 2 * (p["w"] - target)}
        p, opt, _ = adamw_update(g, opt, p, cfg)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target), atol=1e-2)


def test_grad_accum_equivalence():
    """grad_accum=2 == full-batch step (linear model, mean loss)."""
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    opt_cfg = AdamWConfig(lr=0.05, grad_clip=None)
    step1 = make_train_step(loss_fn, opt_cfg, grad_accum=1)
    step2 = make_train_step(loss_fn, opt_cfg, grad_accum=2)
    params = {"w": jnp.asarray([0.3, -0.1])}
    opt = init_opt_state(params)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 2))
    y = x @ jnp.asarray([1.0, 2.0])
    batch = {"x": x, "y": y}
    p1, _, m1 = jax.jit(step1)(params, opt, batch)
    p2, _, m2 = jax.jit(step2)(params, opt, batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-4)


def test_grad_accum_metrics_averaged():
    """Aux metrics must average over microbatches, not keep the last one."""
    def loss_fn(params, batch):
        pred = batch["x"] * params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {"mean_x": jnp.mean(batch["x"])}

    step = make_train_step(loss_fn, AdamWConfig(lr=0.0, grad_clip=None), grad_accum=2)
    params = {"w": jnp.asarray(1.0)}
    opt = init_opt_state(params)
    # microbatch means are 1.0 and 3.0 -> averaged metric must be 2.0
    batch = {"x": jnp.asarray([1.0, 1.0, 3.0, 3.0]), "y": jnp.zeros(4)}
    _, _, metrics = jax.jit(step)(params, opt, batch)
    np.testing.assert_allclose(float(metrics["mean_x"]), 2.0, rtol=1e-6)


def test_warmup_cosine():
    sched = warmup_cosine(1.0, warmup=10, total=110)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(10)), 1.0, rtol=1e-5)
    assert float(sched(110)) < 1e-3
    assert float(sched(5)) == pytest.approx(0.5)


def test_zero1_specs():
    from jax.sharding import PartitionSpec as P
    from repro.core.partition import make_mesh

    mesh = make_mesh((1,), ("data",))  # sizes only matter via mesh.shape
    specs = {"a": P(None, "model"), "b": P()}
    params = {
        "a": jax.ShapeDtypeStruct((7, 16), jnp.float32),   # 7 not divisible
        "b": jax.ShapeDtypeStruct((8, 3), jnp.float32),
    }
    out = zero1_specs(specs, params, mesh, dp_axes=("data",))
    assert out["a"] == P("data", "model")  # dim0 divisible by 1
    assert out["b"] == P("data", None)


def test_checkpoint_roundtrip_and_keep():
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "c": (jnp.ones((2,), jnp.complex64) * (1 + 2j)),
        "n": {"b": jnp.asarray(3, jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4):
            ck.save(d, step, tree, keep=2)
        assert ck.all_steps(d) == [3, 4]
        abstract = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        restored, step, _ = ck.restore(d, abstract)
        assert step == 4
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            restored, tree,
        )


def test_checkpoint_async_and_atomic():
    tree = {"w": jnp.ones((64, 64))}
    with tempfile.TemporaryDirectory() as d:
        path, t = ck.save(d, 7, tree, async_save=True)
        t.join()
        assert os.path.exists(os.path.join(path, "manifest.json"))
        assert not os.path.exists(path + ".tmp")


def test_supervisor_fault_recovery():
    """Injected failures -> restore from checkpoint -> loss path continues."""
    def init_state():
        return {"w": jnp.zeros(2), "step_count": jnp.zeros((), jnp.int32)}

    def train_step(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return (
            {"w": w, "step_count": state["step_count"] + 1},
            {"loss": jnp.sum((w - batch) ** 2)},
        )

    target = jnp.asarray([1.0, 2.0])
    with tempfile.TemporaryDirectory() as d:
        res = run_supervised(
            init_state=init_state,
            train_step=train_step,
            batch_iter=lambda step: target,
            total_steps=30,
            ckpt_dir=d,
            save_every=5,
            injector=FaultInjector([7, 19]),
        )
    assert res.failures == 2
    assert res.restores == 2
    assert res.final_step == 30
    losses = [m["loss"] for _, m in res.metrics_log]
    assert losses[-1] < losses[0]


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0)
    for i in range(10):
        wd.observe(i, 1.0)
    assert wd.observe(10, 5.0) is True
    assert wd.flagged and wd.flagged[0][0] == 10
