"""Serving engine: continuous batching correctness across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import init_lm_params, lm_prefill
from repro.models.policy import LOCAL
from repro.serve import Engine, Request


@pytest.mark.parametrize("arch_id", ["gemma-7b", "mamba2-370m", "deepseek-v2-lite-16b", "recurrentgemma-2b"])
def test_engine_families(arch_id):
    cfg = reduced(get_arch(arch_id))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=48, max_batch=3)
    for r in range(5):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3 + r, 4], max_tokens=5))
    done = eng.run_until_done()
    assert len(done) == 5
    assert all(len(r.output) == 5 for r in done)


def test_engine_matches_teacher_forcing():
    """Greedy engine output == argmax chain from repeated full prefills."""
    cfg = reduced(get_arch("chatglm3-6b"))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 9, 2, 7]
    n_new = 4

    seq = list(prompt)
    for _ in range(n_new):
        logits, _ = jax.jit(lambda p, t: lm_prefill(p, t, cfg, LOCAL))(
            params, jnp.asarray([seq], jnp.int32)
        )
        seq.append(int(jnp.argmax(logits[0])))
    expected = seq[len(prompt):]

    eng = Engine(cfg, params, max_len=32, max_batch=2)
    eng.submit(Request(rid=0, prompt=prompt, max_tokens=n_new))
    done = eng.run_until_done()
    assert done[0].output == expected, (done[0].output, expected)


def test_engine_continuous_admission():
    """More requests than slots: later requests admitted as slots free."""
    cfg = reduced(get_arch("gemma-7b"))
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=32, max_batch=2)
    for r in range(6):
        eng.submit(Request(rid=r, prompt=[r + 1, 2], max_tokens=3))
    done = eng.run_until_done()
    assert sorted(r.rid for r in done) == list(range(6))
    # with 2 slots and 6 requests x 2 decode steps each, the engine must
    # have interleaved (steps strictly less than sequential worst case)
    assert eng.steps <= 6 * 3
