"""Sharded data pipeline: loader properties, datagen round trip, store IO.

Device-count-sensitive checks (the real (data, mx, my) mesh, chunk-read
accounting per pencil) live in loader_checks.py, run as a subprocess with 8
simulated devices; here we cover the device-count-agnostic properties and
the datagen CLI round trip.
"""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import ArrayStore, NdArraySource, ShardedDatasetLoader
from repro.core.partition import make_mesh
from jax.sharding import PartitionSpec as P

SPEC6 = P(("data",), None, None, None, None, None)


def _write_store(root, data, chunks):
    st_ = ArrayStore.create(root, data.shape, "f4", chunks)
    for i in range(data.shape[0]):
        st_.write_sample(i, data[i])
    return st_


# ---------------------------------------------------------------------------
# Loader properties (1-device mesh; the sharded mesh runs in loader_checks)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(3, 9),
    batch=st.integers(1, 4),
    cx=st.sampled_from([1, 2, 4]),
    step=st.integers(0, 11),
)
def test_loader_matches_full_materialization(n, batch, cx, step):
    """Property: any (n, batch, chunking, step) -> bit-identical batches."""
    data = np.random.default_rng(n * 100 + batch).normal(
        size=(n, 1, 4, 4, 2, 2)
    ).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = _write_store(f"{d}/x", data, (1, 1, 4 // cx, 4, 2, 2))
        mesh = make_mesh((1,), ("data",))
        with ShardedDatasetLoader(
            {"x": store}, mesh, batch, {"x": SPEC6}, seed=5, normalize=(),
            prefetch=0,
        ) as loader:
            got = np.asarray(loader.batch(step)["x"])
            ids = loader.sample_ids(step)
            np.testing.assert_array_equal(got, data[ids])
            # the shuffled schedule covers each sample once per epoch
            assert len(ids) == batch
            assert (ids >= 0).all() and (ids < n).all()


def test_loader_prefetch_equals_sync_and_replay():
    data = np.random.default_rng(3).normal(size=(6, 1, 4, 4, 2, 2)).astype(np.float32)
    src = NdArraySource(data)
    mesh = make_mesh((1,), ("data",))
    sync = ShardedDatasetLoader({"x": src}, mesh, 2, {"x": SPEC6}, prefetch=0, normalize=())
    pre = ShardedDatasetLoader({"x": src}, mesh, 2, {"x": SPEC6}, prefetch=2, normalize=())
    try:
        # sequential, then a replay jump backwards (checkpoint restore path)
        for step in (0, 1, 2, 3, 1, 2, 9, 10):
            np.testing.assert_array_equal(
                np.asarray(pre.batch(step)["x"]), np.asarray(sync.batch(step)["x"])
            )
    finally:
        sync.close()
        pre.close()


def test_loader_normalization_from_meta_stats():
    data = np.random.default_rng(4).normal(
        loc=3.0, scale=2.0, size=(5, 2, 4, 4, 2, 2)
    ).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = _write_store(f"{d}/x", data, (1, 2, 4, 4, 2, 2))
        mean = data.mean(axis=(0, 2, 3, 4, 5))
        std = data.std(axis=(0, 2, 3, 4, 5), ddof=1)
        store.update_meta(stats={"mean": mean.tolist(), "std": std.tolist()})
        reopened = ArrayStore.open(f"{d}/x")  # stats survive reopen
        assert reopened.meta["stats"]["mean"] == mean.tolist()
        mesh = make_mesh((1,), ("data",))
        with ShardedDatasetLoader(
            {"x": reopened}, mesh, 5, {"x": SPEC6}, shuffle=False,
            normalize=("x",), prefetch=0,
        ) as loader:
            got = np.asarray(loader.batch(0)["x"])
        ref = (data - mean.reshape(1, -1, 1, 1, 1, 1)) / std.reshape(1, -1, 1, 1, 1, 1)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
        assert abs(got.mean()) < 0.05 and abs(got.std() - 1.0) < 0.05


def test_loader_prefetch_surfaces_missing_chunk_errors():
    """A missing sample must raise (naming the chunk), never hang."""
    data = np.ones((4, 1, 4, 4, 2, 2), np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", data.shape, "f4", (1, 1, 4, 4, 2, 2))
        for i in (0, 1, 2):  # sample 3 never written
            store.write_sample(i, data[i])
        mesh = make_mesh((1,), ("data",))
        with ShardedDatasetLoader(
            {"x": store}, mesh, 4, {"x": SPEC6}, shuffle=False,
            normalize=(), prefetch=2,
        ) as loader:
            with pytest.raises(FileNotFoundError, match="chunk"):
                for step in range(3):
                    loader.batch(step)


def test_loader_rejects_mismatched_sources():
    a = NdArraySource(np.zeros((4, 1, 4, 4, 2, 2), np.float32))
    b = NdArraySource(np.zeros((5, 1, 4, 4, 2, 2), np.float32))
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="sample count"):
        ShardedDatasetLoader(
            {"x": a, "y": b}, mesh, 2, {"x": SPEC6, "y": SPEC6}, prefetch=0
        )


# ---------------------------------------------------------------------------
# Store: multi-chunk samples, completeness, descriptive errors
# ---------------------------------------------------------------------------

def test_store_multichunk_sample_roundtrip_and_completeness():
    data = np.arange(2 * 1 * 8 * 4, dtype=np.float32).reshape(2, 1, 8, 4)
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", data.shape, "f4", (1, 1, 4, 2))
        store.write_sample(0, data[0])
        assert store.sample_complete(0) and not store.sample_complete(1)
        assert store.n_complete() == 1
        np.testing.assert_array_equal(
            store.read_slice((slice(0, 1),) + (slice(None),) * 3)[0], data[0]
        )
        # a partially-written sample is not complete
        store.write_chunk((1, 0, 0, 0), data[1][None, :, :4, :2])
        assert not store.sample_complete(1)
        assert store.n_complete() == 1


def test_store_missing_chunk_error_names_index():
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", (2, 4), "f4", (1, 4))
        with pytest.raises(FileNotFoundError, match=r"chunk \(1, 0\)"):
            store.read_chunk((1, 0))


def test_store_io_counters():
    data = np.ones((2, 8), np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = ArrayStore.create(f"{d}/x", data.shape, "f4", (1, 4))
        for i in range(2):
            store.write_sample(i, data[i])
        store.read_slice((slice(0, 1), slice(0, 8)))
        assert store.io_counters["chunks_read"] == 2
        assert store.io_counters["bytes_read"] == 32
        store.reset_io_counters()
        store.read_slice((slice(0, 2), slice(0, 3)))  # one chunk per row
        assert store.io_counters["chunks_read"] == 2


# ---------------------------------------------------------------------------
# Welford streaming stats
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 6), c=st.integers(1, 3))
def test_streaming_stats_match_direct(n, c):
    from repro.launch.datagen import compute_store_stats

    data = np.random.default_rng(n + 10 * c).normal(
        loc=1.5, scale=3.0, size=(n, c, 6, 4, 2, 2)
    ).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = _write_store(f"{d}/x", data, (1, c, 3, 2, 2, 2))
        stats = compute_store_stats(store)
        np.testing.assert_allclose(
            stats["mean"], data.mean(axis=(0, 2, 3, 4, 5), dtype=np.float64),
            rtol=1e-5, atol=1e-6,
        )
        np.testing.assert_allclose(
            stats["std"], data.std(axis=(0, 2, 3, 4, 5), ddof=1), rtol=1e-4
        )
        assert stats["n_samples"] == n


# ---------------------------------------------------------------------------
# Datagen CLI round trip + the 8-device sharded mesh checks
# ---------------------------------------------------------------------------

@pytest.mark.timeout(600)
def test_datagen_store_loader_roundtrip():
    """Tiny end-to-end: datagen CLI -> chunked store -> loader batches."""
    from repro.launch.datagen import main as datagen_main

    with tempfile.TemporaryDirectory() as d:
        out = f"{d}/ds"
        argv = [
            "--pde", "two_phase", "--n", "4", "--grid", "8", "8", "4",
            "--nt", "2", "--out", out, "--backend", "thread",
            "--workers", "3", "--chunks-xy", "2", "2", "--resume",
        ]
        assert datagen_main(argv) == 4
        # idempotent: rerun simulates nothing, stats unchanged
        xs = ArrayStore.open(f"{out}/x")
        stats_before = xs.meta["stats"]
        assert datagen_main(argv) == 4
        assert ArrayStore.open(f"{out}/x").meta["stats"] == stats_before

        xs, ys = ArrayStore.open(f"{out}/x"), ArrayStore.open(f"{out}/y")
        assert xs.shape == (4, 1, 8, 8, 4, 2) and ys.shape == xs.shape
        assert xs.chunks == (1, 1, 4, 4, 4, 2)
        mesh = make_mesh((1,), ("data",))
        with ShardedDatasetLoader(
            {"x": xs, "y": ys}, mesh, 2, {"x": SPEC6, "y": SPEC6},
            normalize=("x",),
        ) as loader:
            for step in range(3):
                b = loader.batch(step)
                assert b["x"].shape == (2, 1, 8, 8, 4, 2)
                assert np.isfinite(np.asarray(b["x"])).all()
                assert np.isfinite(np.asarray(b["y"])).all()
            # saturation target is untouched; mask input is normalized
            assert float(np.asarray(b["y"]).max()) <= 1.0
            assert abs(float(np.asarray(b["x"]).mean())) < 5.0


@pytest.mark.timeout(1200)
def test_sharded_loader_checks():
    """Chunk accounting + bit-identity on a real (data, mx, my) mesh."""
    script = os.path.join(os.path.dirname(__file__), "loader_checks.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True,
        text=True,
        timeout=1200,
        env=env,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "loader checks failed (see output)"
    assert "ALL_LOADER_CHECKS_PASSED" in proc.stdout


@pytest.mark.timeout(1200)
def test_datagen_to_sharded_train_cli_smoke():
    """The acceptance path: datagen CLI -> train CLI on 8 devices with a
    2x2 pencil, loss decreasing, through shard_train_step."""
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as d:
        gen = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.datagen",
                "--pde", "two_phase", "--n", "8", "--grid", "8", "8", "4",
                "--nt", "4", "--out", f"{d}/ds", "--backend", "thread",
                "--workers", "4",
            ],
            capture_output=True, text=True, timeout=600, env=env, cwd=repo,
        )
        sys.stdout.write(gen.stdout)
        assert gen.returncode == 0, gen.stderr[-4000:]
        assert "8/8 samples complete" in gen.stdout

        tr = subprocess.run(
            [
                sys.executable, os.path.join(repo, "src", "repro", "launch", "train.py"),
                "--mode", "fno", "--x-store", f"{d}/ds/x", "--y-store", f"{d}/ds/y",
                "--steps", "12", "--batch", "2", "--lr", "3e-3",
                "--devices", "8", "--model-shards", "2", "2",
                "--ckpt-dir", f"{d}/ckpt", "--save-every", "6",
            ],
            capture_output=True, text=True, timeout=900, env=env, cwd=repo,
        )
        sys.stdout.write(tr.stdout)
        sys.stderr.write(tr.stderr[-4000:])
        assert tr.returncode == 0
        assert "done: steps=12" in tr.stdout
        line = [l for l in tr.stdout.splitlines() if l.startswith("done:")][0]
        first, last = (
            float(tok) for tok in line.split("loss ")[1].split(" stragglers")[0].split(" -> ")
        )
        assert last < first, line
