"""Per-kernel validation: shape/dtype sweeps + hypothesis vs ref.py oracles,
all in Pallas interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import attention_chunked, attention_ref, flash_attention
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.spectral_conv import spectral_apply, spectral_apply_ref


# ---------------------------------------------------------------------------
# spectral_conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,ci,co,modes", [
    (1, 4, 4, (2, 2, 2, 2)),
    (2, 6, 5, (4, 4, 2, 3)),
    (3, 8, 8, (3, 5, 1, 2)),
])
def test_spectral_conv_shapes(b, ci, co, modes):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    xf = (jax.random.normal(k1, (b, ci) + modes) + 1j * jax.random.normal(k2, (b, ci) + modes)).astype(jnp.complex64)
    w = (jax.random.normal(k2, (ci, co) + modes) + 1j * jax.random.normal(k1, (ci, co) + modes)).astype(jnp.complex64)
    ref = spectral_apply_ref(xf, w)
    out = spectral_apply(xf, w, use_pallas=True, block_k=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    ci=st.integers(1, 8),
    co=st.integers(1, 8),
    k1=st.integers(1, 6),
    k2=st.integers(1, 5),
    block_k=st.sampled_from([4, 8, 16]),
)
def test_spectral_conv_hypothesis(b, ci, co, k1, k2, block_k):
    key = jax.random.PRNGKey(b * 100 + ci * 10 + co)
    ka, kb = jax.random.split(key)
    xf = (jax.random.normal(ka, (b, ci, k1, k2)) + 1j * jax.random.normal(kb, (b, ci, k1, k2))).astype(jnp.complex64)
    w = (jax.random.normal(kb, (ci, co, k1, k2)) + 1j * jax.random.normal(ka, (ci, co, k1, k2))).astype(jnp.complex64)
    ref = spectral_apply_ref(xf, w)
    out = spectral_apply(xf, w, use_pallas=True, block_k=block_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kvh,sq,sk,d,causal,dtype", [
    (2, 4, 2, 128, 128, 32, True, jnp.float32),
    (1, 4, 1, 100, 260, 16, True, jnp.float32),     # padding + MQA
    (2, 2, 2, 64, 192, 64, False, jnp.float32),     # cross-attn style
    (1, 8, 4, 128, 128, 32, True, jnp.bfloat16),    # bf16
])
def test_flash_attention_sweep(b, h, kvh, sq, sk, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kvh, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, kvh, sk, d), dtype)
    ref = attention_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, use_pallas=True, block_q=64, block_k=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@settings(max_examples=8, deadline=None)
@given(
    sq=st.integers(1, 96),
    sk=st.integers(8, 200),
    chunk=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
)
def test_attention_chunked_hypothesis(sq, sk, chunk, causal):
    if causal and sk < sq:
        sk = sq
    ks = jax.random.split(jax.random.PRNGKey(sq * 7 + sk), 3)
    q = jax.random.normal(ks[0], (1, 2, sq, 16))
    k = jax.random.normal(ks[1], (1, 2, sk, 16))
    v = jax.random.normal(ks[2], (1, 2, sk, 16))
    ref = attention_ref(q, k, v, causal=causal)
    out = attention_chunked(q, k, v, causal=causal, chunk_k=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,d,dtype,block", [
    (64, 128, jnp.float32, 16),
    (37, 256, jnp.bfloat16, 16),   # padding path
    (256, 64, jnp.float32, 256),
])
def test_rmsnorm_sweep(rows, d, dtype, block):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    ref = rmsnorm_ref(x, w)
    out = rmsnorm(x, w, use_pallas=True, block_rows=block)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 64), d=st.sampled_from([8, 32, 128]), eps=st.sampled_from([1e-6, 1e-5]))
def test_rmsnorm_hypothesis(rows, d, eps):
    x = jax.random.normal(jax.random.PRNGKey(rows + d), (rows, d))
    w = jnp.ones((d,))
    out = rmsnorm(x, w, eps=eps, use_pallas=True, block_rows=8)
    # invariant: rms of output rows ~= 1 for unit weights
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(rows), rtol=2e-2, atol=2e-2)
