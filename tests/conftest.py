import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Prefer the real hypothesis (declared in requirements-dev.txt); fall back
# to the vendored deterministic shim when the wheel isn't installed, so the
# property-test modules always collect and run.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

# Main-process tests must be device-count agnostic: local runs see 1 CPU
# device, CI exports XLA_FLAGS=--xla_force_host_platform_device_count=8
# (the tier-1 command in .github/workflows/ci.yml). Tests that NEED a
# specific device count always spawn a subprocess and set their own flag
# (launch/dryrun.py, distributed_checks.py, test_hlo/test_dfft_2d scripts);
# test_distributed strips the inherited XLA_FLAGS before doing so.
