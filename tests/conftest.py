import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests must see the real single-device CPU (the 512-device flag is
# set ONLY inside launch/dryrun.py and the distributed-test subprocesses).
