"""Cloud batch layer: API semantics, scaling model, straggler mitigation."""
import tempfile
import time

import numpy as np
import pytest

from repro.cloud import (
    BatchPool, BlobRef, ObjectStore, SimBackend, SimConfig, ThreadBackend,
)


def _square(x):
    return x * x


def _add_ref(a, b):
    return a + b


def _slow_if_first(task_tag, delay):
    if task_tag == 0:
        time.sleep(delay)
    return task_tag


def test_object_store_roundtrip_and_dedup():
    with tempfile.TemporaryDirectory() as d:
        store = ObjectStore(d)
        arr = np.arange(1000, dtype=np.float32)
        r1 = store.put(arr)
        r2 = store.put(arr)
        assert r1.key == r2.key  # content addressed
        np.testing.assert_array_equal(store.get(r1), arr)


def test_pool_map_and_broadcast():
    with tempfile.TemporaryDirectory() as d:
        pool = BatchPool(ThreadBackend(4), store_root=d, vm_type="E4s_v3", n_vms=4)
        big = pool.broadcast(np.ones(100))
        assert isinstance(big, BlobRef)
        out = pool.map(_add_ref, [(i, big) for i in range(6)])
        for i, o in enumerate(out):
            np.testing.assert_array_equal(o, i + np.ones(100))
        rep = pool.cost_report()
        assert rep["tasks"] == 6 and rep["usd"] >= 0
        pool.shutdown()


def test_speculative_straggler():
    with tempfile.TemporaryDirectory() as d:
        pool = BatchPool(ThreadBackend(6), store_root=d, n_vms=6)
        out = pool.map(
            _slow_if_first,
            [(i, 2.0 if i == 0 else 0.01) for i in range(6)],
            speculative=True,
            straggler_factor=3.0,
        )
        assert out == list(range(6))
        pool.shutdown()


def test_speculative_reuses_uploaded_arg_refs():
    """Backup tasks must reuse the first submission's BlobRefs (no re-upload)."""
    with tempfile.TemporaryDirectory() as d:
        pool = BatchPool(ThreadBackend(6), store_root=d, n_vms=6)
        puts = []
        orig_put = pool.store.put
        pool.store.put = lambda obj: (puts.append(1), orig_put(obj))[1]
        out = pool.map(
            _slow_if_first,
            [(i, 2.0 if i == 0 else 0.01) for i in range(6)],
            speculative=True,
            straggler_factor=3.0,
        )
        assert out == list(range(6))
        rec = pool.records[0]
        assert rec.speculated and rec.arg_refs is not None
        # 2 args x 6 tasks uploaded once; result blobs are stored worker-side
        # through a separate ObjectStore instance, so any extra put here
        # would be a speculative re-upload
        assert len(puts) == 12, len(puts)
        pool.shutdown()


def test_sim_submission_linear():
    """Paper Fig. 4a: submission time ~linear in tasks; ~16s @ 1024 tasks."""
    sim = SimBackend(SimConfig())
    t64 = sim.run_job(64, 64, 60.0).submit_time_s
    t1024 = sim.run_job(1024, 64, 60.0).submit_time_s
    assert t1024 > t64
    assert 10.0 < t1024 < 25.0  # calibrated to the paper's ~16 s
    # linearity: doubling tasks roughly doubles the per-task component
    t2048 = sim.run_job(2048, 64, 60.0).submit_time_s
    np.testing.assert_allclose(t2048 - t1024, t1024 - sim.cfg.submit_base_s, rtol=0.1)


def test_sim_weak_scaling_paper_metric():
    """Paper Fig. 4b: >=99% for both workloads at paper scale."""
    sim = SimBackend(SimConfig())
    ns = sim.run_job(3200, 1000, 15 * 60.0)
    co2 = sim.run_job(1600, 1000, 6.8 * 3600.0)
    assert ns.weak_scaling_efficiency(15 * 60.0) > 0.98
    assert co2.weak_scaling_efficiency(6.8 * 3600.0) > 0.99
    # end-to-end (with startup + quantization) is necessarily lower
    assert co2.end_to_end_efficiency(6.8 * 3600.0) < 1.0


def test_sim_spot_preemption_retries():
    sim = SimBackend(SimConfig(spot=True, spot_preempt_per_hour=2.0, seed=1))
    rep = sim.run_job(50, 10, 1800.0)
    assert rep.preemptions > 0
    assert len(rep.task_end_times) == 50  # every task eventually completed
    assert rep.total_core_seconds > 50 * 1800.0  # wasted work from preemptions


def test_array_store_parallel_write_pattern():
    """Disjoint chunk writes from multiple 'tasks' + partial reads."""
    from repro.data.store import ArrayStore

    with tempfile.TemporaryDirectory() as d:
        st = ArrayStore.create(f"{d}/arr", (4, 8, 8), "f4", (1, 8, 8))
        for i in range(4):
            st.write_chunk((i, 0, 0), np.full((1, 8, 8), i, np.float32))
        assert st.n_complete() == 4
        got = ArrayStore.open(f"{d}/arr").read_slice((slice(1, 3), slice(2, 6), slice(0, 8)))
        assert got.shape == (2, 4, 8)
        np.testing.assert_array_equal(got[0], np.full((4, 8), 1))
        np.testing.assert_array_equal(got[1], np.full((4, 8), 2))
